//! The cluster coordinator process: registration, shard assignment, config
//! distribution, telemetry aggregation, checkpointing, failure recovery,
//! and the final report.
//!
//! The coordinator never touches the gossip plane — workers exchange model
//! payloads peer-to-peer. Its control plane carries five things:
//!
//! 1. **Assign** — rank, the full run config (as INI text, the same format
//!    `--config` reads), the node shard, and every peer's gossip address;
//! 2. **Progress** — cumulative counters, streamed as heartbeats; the sum
//!    of the latest snapshots decides when the interaction target is hit;
//! 3. **Checkpoint** — each worker's owned slots, persisted periodically
//!    via [`output::checkpoint`](crate::output::checkpoint) so a dead
//!    worker's shard can be reassigned from its last published state;
//! 4. **Adopt** — the recovery broadcast: every live worker updates its
//!    owner map, the adopter additionally resumes the orphaned nodes;
//! 5. **Done/Shutdown** — the drain handshake at the interaction target.
//!
//! Failure detection is heartbeat-based: a worker whose last `Progress` is
//! older than `heartbeat_timeout` seconds (or whose socket drops) is
//! declared dead and its shard moves to the lowest live rank.

use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::proto::{done_staleness, Msg, NodeLanes, PeerAddr, ProgressBody};
use super::transport::{send_msg, FrameConn};
use crate::backend::{build_backend, Backend};
use crate::config::RunConfig;
use crate::coordinator::{
    Algorithm, PayloadKind, PlainModel, PushSumWeighted, SlotPayload, StalenessHistogram,
};
use crate::obs::{
    self, HttpServer, MetricsRegistry, Response, Router, SpanKind, TraceDrain, TraceRing,
};
use crate::output::checkpoint::save_npy;

/// What the coordinator reports when the cluster run completes.
#[derive(Debug)]
pub struct ClusterReport {
    /// total interactions across all workers (from final Done counters)
    pub events: u64,
    pub wall_secs: f64,
    /// real socket bits on the gossip plane, summed over workers
    pub wire_bits: u64,
    /// shard reassignments performed after heartbeat-timeout detections
    pub recoveries: u32,
    /// consensus-model loss on the coordinator's own backend
    pub final_eval_loss: f64,
    pub interactions_per_sec: f64,
}

/// Per-worker bookkeeping on the coordinator.
struct WorkerSlot {
    rank: u32,
    stream: TcpStream,
    alive: bool,
    done: bool,
    last_seen: Instant,
    progress: ProgressBody,
    /// the worker's last checkpointed shard (node → lanes)
    checkpoint: Vec<NodeLanes>,
    /// nodes currently owned (moves on adoption)
    shard: usize,
    /// roster epoch of the worker's current shard: 0 for the initial
    /// assignment, bumped to the reassignment's epoch whenever nodes move
    /// to or from this worker (the membership subsystem's generation idea
    /// applied to shard ownership)
    epoch: u32,
    /// last measured control-plane ping round-trip, µs (None until the
    /// first Pong lands)
    rtt_us: Option<f64>,
}

enum Event {
    Msg(u32, Msg),
    Gone(u32),
}

/// Run the coordinator: listen on `listen`, register `cfg.workers` workers,
/// drive the run to `cfg.interactions` total interactions, and report.
/// `checkpoint_dir` receives `cluster_ckpt.npy` (periodic) and, when
/// `cfg.out_npy` behavior is wanted, the final consensus model.
pub fn run_coordinator(
    cfg: &RunConfig,
    listen: &str,
    checkpoint_dir: &Path,
) -> Result<ClusterReport, String> {
    let algo = crate::coordinator::make_algorithm(
        &cfg.algo,
        &crate::coordinator::AlgoOptions {
            local_steps: cfg.local_steps(),
            mode: cfg.averaging_mode()?,
            h_localsgd: cfg.h.round().max(0.0) as u64,
            wire: cfg.wire_codec()?,
            kernel: cfg.kernel_enum()?,
        },
    )?;
    let policy = algo.mix_policy().ok_or_else(|| {
        format!(
            "--executor cluster requires a free-running MixPolicy \
             (cluster-eligible: swarm, poisson, adpsgd, dpsgd, and sgp via \
             weighted push-sum slots); '{}' mixes through an irreducible \
             global mean — use --executor serial|parallel",
            cfg.algo
        )
    })?;
    // resolve the scenario once up front: an infeasible topology/n combo,
    // a bad speed spec, or an invalid graph schedule fails here with the
    // actionable config error — before any worker is handed the job
    crate::scenario::Scenario::from_config(cfg)?;
    let backend = build_backend(cfg)?;
    match policy.payload() {
        PayloadKind::Plain => {
            coordinate::<PlainModel>(cfg, algo.as_ref(), backend.as_ref(), listen, checkpoint_dir)
        }
        PayloadKind::PushSumWeighted => coordinate::<PushSumWeighted>(
            cfg,
            algo.as_ref(),
            backend.as_ref(),
            listen,
            checkpoint_dir,
        ),
    }
}

fn coordinate<P: SlotPayload>(
    cfg: &RunConfig,
    algo: &dyn Algorithm,
    backend: &dyn Backend,
    listen: &str,
    checkpoint_dir: &Path,
) -> Result<ClusterReport, String> {
    let io = |e: std::io::Error| format!("cluster coordinator: {e}");
    let workers = cfg.workers as u32;
    let n = cfg.n;
    let dim = backend.dim();
    let lanes = P::lanes(dim);
    let (p0, _) = backend.init();

    let listener = TcpListener::bind(listen).map_err(io)?;
    let local = listener.local_addr().map_err(io)?;
    // tests and operators parse this exact line to learn the bound port
    println!("cluster coordinator listening on {local} (waiting for {workers} workers)");
    use std::io::Write;
    std::io::stdout().flush().ok();

    // ---- live introspection endpoint (--metrics-addr) ----
    // the registry and status document are refreshed by the control loop
    // each cadence; the HTTP thread only ever renders/clones them, so the
    // endpoint can never block the control plane
    let registry = MetricsRegistry::new();
    let g_workers = registry.gauge("swarm_cluster_workers", "registered workers");
    let g_alive = registry.gauge("swarm_cluster_workers_alive", "workers currently alive");
    let g_ips = registry.gauge("swarm_interactions_per_sec", "throughput over the last cadence");
    let g_rtt = registry.gauge("swarm_heartbeat_rtt_us_mean", "mean control-plane ping RTT (us)");
    let g_age =
        registry.gauge("swarm_worker_progress_age_sec_max", "oldest last-progress age (s)");
    let c_events = registry.counter("swarm_interactions_total", "interactions across workers");
    let c_bits = registry.counter("swarm_wire_bits_total", "real socket bits, gossip plane");
    let c_fallbacks = registry.counter("swarm_wire_fallbacks_total", "codec decode fallbacks");
    let c_conflicts =
        registry.counter("swarm_push_conflicts_total", "cross-writes dropped to a held slot");
    let status: Arc<Mutex<String>> = Arc::new(Mutex::new("{}".to_string()));
    // control-plane trace: one Heartbeat event per Progress receipt, served
    // as a best-effort drain-so-far by /trace (enabled with the endpoint)
    let ctl_trace = Arc::new(TraceRing::new(if cfg.metrics_addr.is_empty() {
        0
    } else {
        obs::DEFAULT_TRACE_CAPACITY
    }));
    let _http = if cfg.metrics_addr.is_empty() {
        None
    } else {
        let reg = registry.clone();
        let st = status.clone();
        let tr = ctl_trace.clone();
        let router = Router::new()
            .route("/metrics", move || Response::text(200, reg.render()))
            .route("/status", move || Response::json(st.lock().unwrap().clone()))
            .route("/trace", move || {
                Response::json(TraceDrain::from_rings([&*tr]).to_chrome_json())
            });
        let srv = HttpServer::spawn(&cfg.metrics_addr, router).map_err(io)?;
        // tests parse this exact line to learn the bound port
        println!("cluster metrics serving on {}", srv.addr());
        std::io::stdout().flush().ok();
        Some(srv)
    };
    let mut metrics_file = match cfg.metrics_out.as_str() {
        "" => None,
        path => match std::fs::File::create(path) {
            Ok(f) => Some(f),
            Err(e) => {
                obs::log::warn(
                    "cluster",
                    format_args!("cannot create metrics file '{path}': {e}; export disabled"),
                );
                None
            }
        },
    };

    // ---- registration: accept Hellos, learn gossip addresses ----
    let mut conns: Vec<(FrameConn, String)> = Vec::new();
    while conns.len() < workers as usize {
        let (stream, peer) = listener.accept().map_err(io)?;
        stream.set_nodelay(true).ok();
        let mut conn = FrameConn::new(stream);
        match conn.read_msg().map_err(io)? {
            Some(Msg::Hello { gossip_port }) => {
                let gossip = format!("{}:{}", peer.ip(), gossip_port);
                println!("cluster: worker {} registered (gossip {gossip})", conns.len());
                conns.push((conn, gossip));
            }
            m => return Err(format!("cluster coordinator: expected Hello, got {m:?}")),
        }
    }
    let peers: Vec<PeerAddr> = conns
        .iter()
        .enumerate()
        .map(|(r, (_, addr))| PeerAddr { rank: r as u32, addr: addr.clone() })
        .collect();

    // ---- assignment: node k lives on rank k mod W; ship the config ----
    let config_ini = cfg.to_ini();
    let mut slots: Vec<WorkerSlot> = Vec::new();
    let mut readers: Vec<FrameConn> = Vec::new();
    for (rank, (conn, _)) in conns.into_iter().enumerate() {
        let rank = rank as u32;
        let owned: Vec<u32> = (0..n as u32).filter(|k| k % workers == rank).collect();
        let shard = owned.len();
        let mut stream = conn.stream.try_clone().map_err(io)?;
        send_msg(
            &mut stream,
            &Msg::Assign {
                rank,
                workers,
                config_ini: config_ini.clone(),
                owned,
                peers: peers.clone(),
            },
        )
        .map_err(io)?;
        slots.push(WorkerSlot {
            rank,
            stream,
            alive: true,
            done: false,
            last_seen: Instant::now(),
            progress: ProgressBody::default(),
            checkpoint: Vec::new(),
            shard,
            epoch: 0,
            rtt_us: None,
        });
        readers.push(conn);
    }
    let (tx, rx) = mpsc::channel::<Event>();
    for (rank, mut conn) in readers.into_iter().enumerate() {
        let rank = rank as u32;
        let tx = tx.clone();
        std::thread::spawn(move || loop {
            match conn.read_msg() {
                Ok(Some(m)) => {
                    if tx.send(Event::Msg(rank, m)).is_err() {
                        return;
                    }
                }
                Ok(None) | Err(_) => {
                    let _ = tx.send(Event::Gone(rank));
                    return;
                }
            }
        });
    }
    drop(tx);

    let started = Instant::now();
    let timeout = Duration::from_secs_f64(cfg.heartbeat_timeout);
    let ckpt_path: PathBuf = checkpoint_dir.join("cluster_ckpt.npy");
    let mut last_ckpt_write = Instant::now();
    let mut recoveries = 0u32;
    let mut shutting_down = false;
    let mut final_entries: Vec<NodeLanes> = Vec::new();
    let mut staleness = StalenessHistogram::new((8 * n).max(1024));
    // RTT probes carry this monotonic clock's ns; it never leaves the
    // coordinator, so nothing needs to be synchronized across machines
    let ping_epoch = Instant::now();
    let now_ns = move || ping_epoch.elapsed().as_nanos() as u64;
    let mut last_sweep = Instant::now();
    let mut last_sweep_events = 0u64;

    loop {
        match rx.recv_timeout(Duration::from_millis(100)) {
            Ok(Event::Msg(rank, msg)) => {
                if let Msg::Done { .. } = &msg {
                    if let Some(h) = done_staleness(&msg) {
                        staleness.merge(&h);
                    }
                }
                let slot = &mut slots[rank as usize];
                slot.last_seen = Instant::now();
                match msg {
                    Msg::Progress(p) => {
                        if ctl_trace.enabled() {
                            let t = ctl_trace.now_ns();
                            ctl_trace.record(SpanKind::Heartbeat, rank, t, 0, p.events);
                        }
                        slot.progress = p;
                    }
                    Msg::Pong { t_ns } => {
                        slot.rtt_us = Some(now_ns().saturating_sub(t_ns) as f64 / 1_000.0);
                    }
                    Msg::Checkpoint { events, entries } => {
                        slot.checkpoint = entries;
                        if last_ckpt_write.elapsed() >= Duration::from_millis(500) {
                            last_ckpt_write = Instant::now();
                            write_checkpoint::<P>(&ckpt_path, &slots, n, lanes, &p0);
                            // the kill test watches for this line before
                            // injecting a failure
                            println!("cluster: checkpoint at {events} events (worker {rank})");
                            std::io::stdout().flush().ok();
                        }
                    }
                    Msg::Done { entries, progress, .. } => {
                        slot.progress = progress;
                        slot.done = true;
                        final_entries.extend(entries);
                    }
                    m => obs::log::warn(
                        "cluster",
                        format_args!("coordinator: unexpected {m:?} from worker {rank}"),
                    ),
                }
            }
            Ok(Event::Gone(rank)) => {
                let slot = &mut slots[rank as usize];
                if slot.alive && !slot.done && !shutting_down {
                    slot.alive = false;
                    recover::<P>(&mut slots, rank, n, workers, dim, &p0, &mut recoveries)?;
                } else {
                    slot.alive = false;
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                if !shutting_down {
                    return Err("cluster coordinator: all workers disconnected".into());
                }
            }
        }

        // observability sweep: RTT probes out, registry + /status refresh
        if last_sweep.elapsed() >= obs::METRICS_CADENCE {
            let dt = last_sweep.elapsed().as_secs_f64().max(1e-9);
            last_sweep = Instant::now();
            for slot in slots.iter_mut().filter(|s| s.alive && !s.done) {
                let _ = send_msg(&mut slot.stream, &Msg::Ping { t_ns: now_ns() });
            }
            let total: u64 = slots.iter().map(|s| s.progress.events).sum();
            g_workers.set(workers as f64);
            g_alive.set(slots.iter().filter(|s| s.alive).count() as f64);
            g_ips.set(total.saturating_sub(last_sweep_events) as f64 / dt);
            last_sweep_events = total;
            let rtts: Vec<f64> = slots.iter().filter_map(|s| s.rtt_us).collect();
            if !rtts.is_empty() {
                g_rtt.set(rtts.iter().sum::<f64>() / rtts.len() as f64);
            }
            g_age.set(
                slots
                    .iter()
                    .filter(|s| s.alive && !s.done)
                    .map(|s| s.last_seen.elapsed().as_secs_f64())
                    .fold(0.0, f64::max),
            );
            c_events.set(total);
            c_bits.set(slots.iter().map(|s| s.progress.wire_bits).sum());
            c_fallbacks.set(slots.iter().map(|s| s.progress.wire_fallbacks).sum());
            c_conflicts.set(slots.iter().map(|s| s.progress.push_conflicts).sum());
            *status.lock().unwrap() = status_json(
                &slots,
                cfg.interactions,
                total,
                started.elapsed().as_secs_f64(),
                shutting_down,
            );
            if let Some(f) = metrics_file.as_mut() {
                if let Err(e) = obs::metrics::append_snapshot(f, &registry) {
                    obs::log::warn("cluster", format_args!("metrics append failed: {e}"));
                }
            }
        }

        // heartbeat scan (skipped once draining: workers stop heartbeating
        // after Done)
        if !shutting_down {
            let dead: Vec<u32> = slots
                .iter()
                .filter(|s| s.alive && !s.done && s.last_seen.elapsed() > timeout)
                .map(|s| s.rank)
                .collect();
            for rank in dead {
                slots[rank as usize].alive = false;
                println!(
                    "cluster: worker {rank} missed heartbeats for {:.1}s — declaring dead",
                    slots[rank as usize].last_seen.elapsed().as_secs_f64()
                );
                recover::<P>(&mut slots, rank, n, workers, dim, &p0, &mut recoveries)?;
            }
        }

        if slots.iter().all(|s| !s.alive && !s.done) {
            return Err(format!(
                "cluster coordinator: every worker died before reaching \
                 {} interactions",
                cfg.interactions
            ));
        }

        // target check: the sum of the latest cumulative counters
        let total: u64 = slots.iter().map(|s| s.progress.events).sum();
        if !shutting_down && total >= cfg.interactions {
            shutting_down = true;
            println!(
                "cluster: target reached ({total} ≥ {} events) — draining",
                cfg.interactions
            );
            for slot in slots.iter_mut().filter(|s| s.alive) {
                let _ = send_msg(
                    &mut slot.stream,
                    &Msg::Shutdown { reason: "interaction target reached".into() },
                );
            }
        }
        if shutting_down && slots.iter().all(|s| s.done || !s.alive) {
            break;
        }
    }
    let wall = started.elapsed().as_secs_f64();
    // aggregate the last cumulative counter snapshot of every worker
    // (Done supersedes the final Progress heartbeat; dead workers
    // contribute whatever they last reported)
    let mut final_progress = ProgressBody::default();
    for s in &slots {
        final_progress.add(&s.progress);
    }

    // ---- final consensus: Done entries first, checkpoint fill for shards
    // whose worker died mid-drain, init fill as the last resort ----
    let mut by_node: Vec<Option<Vec<f32>>> = vec![None; n];
    for e in &final_entries {
        if (e.node as usize) < n && e.lanes.len() == lanes {
            by_node[e.node as usize] = Some(e.lanes.clone());
        }
    }
    for s in &slots {
        for e in &s.checkpoint {
            let ix = e.node as usize;
            if ix < n && e.lanes.len() == lanes && by_node[ix].is_none() {
                by_node[ix] = Some(e.lanes.clone());
            }
        }
    }
    let mut init = vec![0.0f32; lanes];
    P::encode(&p0, 1.0, &mut init);
    let snaps: Vec<Vec<f32>> =
        by_node.into_iter().map(|o| o.unwrap_or_else(|| init.clone())).collect();
    let consensus = P::consensus(&snaps, dim);
    let eval = backend.eval(&consensus);
    let final_path = checkpoint_dir.join("cluster_final.npy");
    save_npy(&final_path, &consensus).map_err(io)?;

    let events = final_progress.events;
    let report = ClusterReport {
        events,
        wall_secs: wall,
        wire_bits: final_progress.wire_bits,
        recoveries,
        final_eval_loss: eval.loss,
        interactions_per_sec: events as f64 / wall.max(1e-9),
    };
    let rtts: Vec<f64> = slots.iter().filter_map(|s| s.rtt_us).collect();
    let rtt_mean = if rtts.is_empty() {
        f64::NAN
    } else {
        rtts.iter().sum::<f64>() / rtts.len() as f64
    };
    let age_max =
        slots.iter().map(|s| s.last_seen.elapsed().as_secs_f64()).fold(0.0, f64::max);
    println!(
        "\ncluster telemetry ({workers} worker(s) over sockets, wall {wall:.2}s):\n\
         real throughput  : {:.0} interactions/s\n\
         wire codec       : {} ({:.3} GB on the wire, {} decode fallbacks)\n\
         merge kernel     : {:?}\n\
         staleness (events): p50={} p99={} max={} mean={:.1}\n\
         slot contention  : {} read retries, {} publish retries, \
         {} dropped cross-writes\n\
         worker activity  : {:.2}s busy / {:.3}s wire-sync across workers\n\
         heartbeat rtt    : mean {:.0}µs over {} worker(s) with probes\n\
         progress age     : max {:.2}s at drain\n\
         recoveries       : {recoveries} shard reassignment(s)\n\
         model written to : {}",
        report.interactions_per_sec,
        cfg.wire,
        report.wire_bits as f64 / 8e9,
        final_progress.wire_fallbacks,
        algo.kernel(),
        staleness.p50(),
        staleness.p99(),
        staleness.max_observed(),
        staleness.mean(),
        final_progress.read_retries,
        final_progress.publish_retries,
        final_progress.push_conflicts,
        final_progress.busy_us as f64 / 1e6,
        final_progress.wait_us as f64 / 1e6,
        rtt_mean,
        rtts.len(),
        age_max,
        final_path.display(),
    );
    // leave a final status snapshot for any scraper still attached
    *status.lock().unwrap() =
        status_json(&slots, cfg.interactions, events, wall, true);
    // tests parse this line: loss, events, recoveries in one place
    println!(
        "cluster: final eval_loss={:.6} events={events} recoveries={recoveries} \
         wire_bits={}",
        eval.loss, report.wire_bits
    );
    std::io::stdout().flush().ok();
    Ok(report)
}

/// The `/status` JSON document: run-level aggregates plus one entry per
/// worker (shard size, liveness, heartbeat RTT, last-progress age, shard
/// roster epoch). The top-level `roster_epoch` is the current assignment
/// generation: 0 until the first recovery, then the latest adoption's
/// epoch. Hand-rolled like everything on this plane; every value is a
/// JSON number, bool, or null, so any parser handles it.
fn status_json(
    slots: &[WorkerSlot],
    target: u64,
    events: u64,
    wall: f64,
    draining: bool,
) -> String {
    let mut out = String::with_capacity(256 + slots.len() * 160);
    out.push_str(&format!(
        "{{\"workers\":{},\"alive\":{},\"roster_epoch\":{},\"target\":{target},\
         \"events\":{events},\
         \"interactions_per_sec\":{:.1},\"wall_secs\":{wall:.3},\"draining\":{draining},\
         \"per_worker\":[",
        slots.len(),
        slots.iter().filter(|s| s.alive).count(),
        slots.iter().map(|s| s.epoch).max().unwrap_or(0),
        events as f64 / wall.max(1e-9),
    ));
    for (i, s) in slots.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let rtt = match s.rtt_us {
            Some(r) => format!("{r:.1}"),
            None => "null".to_string(),
        };
        out.push_str(&format!(
            "{{\"rank\":{},\"alive\":{},\"done\":{},\"shard_nodes\":{},\"epoch\":{},\
             \"events\":{},\
             \"last_progress_age_sec\":{:.3},\"rtt_us\":{rtt}}}",
            s.rank,
            s.alive,
            s.done,
            s.shard,
            s.epoch,
            s.progress.events,
            s.last_seen.elapsed().as_secs_f64(),
        ));
    }
    out.push_str("]}");
    out
}

/// Reassign a dead worker's shard to the lowest live rank, seeding the
/// adopter from the dead worker's last checkpoint (init params when it died
/// before ever checkpointing). Broadcast to ALL live workers so every
/// owner map converges.
fn recover<P: SlotPayload>(
    slots: &mut [WorkerSlot],
    dead: u32,
    n: usize,
    workers: u32,
    dim: usize,
    p0: &[f32],
    recoveries: &mut u32,
) -> Result<(), String> {
    let adopter = match slots.iter().find(|s| s.alive && !s.done) {
        Some(s) => s.rank,
        None => return Ok(()), // terminal-state check elsewhere reports this
    };
    let lanes = P::lanes(dim);
    let mut init = vec![0.0f32; lanes];
    P::encode(p0, 1.0, &mut init);
    let ckpt = &slots[dead as usize].checkpoint;
    let entries: Vec<NodeLanes> = (0..n as u32)
        .filter(|k| k % workers == dead)
        .map(|k| {
            ckpt.iter()
                .find(|e| e.node == k)
                .cloned()
                .unwrap_or_else(|| NodeLanes { node: k, lanes: init.clone() })
        })
        .collect();
    *recoveries += 1;
    println!(
        "cluster: recovery #{recoveries} — worker {dead} dead, {} node(s) \
         adopted by worker {adopter} from checkpoint",
        entries.len()
    );
    use std::io::Write;
    std::io::stdout().flush().ok();
    // shard bookkeeping for /status: the nodes move with the adoption,
    // under a fresh roster epoch stamping both ends of the move
    let moved = entries.len();
    let epoch = *recoveries;
    slots[dead as usize].shard = 0;
    slots[dead as usize].epoch = epoch;
    slots[adopter as usize].shard += moved;
    slots[adopter as usize].epoch = epoch;
    let msg = Msg::Adopt { to_rank: adopter, from_rank: dead, epoch, entries };
    for slot in slots.iter_mut().filter(|s| s.alive) {
        if send_msg(&mut slot.stream, &msg).is_err() {
            // the Gone event / heartbeat scan will pick this worker up
            obs::log::warn(
                "cluster",
                format_args!("could not notify worker {} of the adoption", slot.rank),
            );
        }
    }
    Ok(())
}

/// Persist the union of every worker's last checkpoint as one flat
/// `[n × lanes]` npy (versioned trailer via `output::checkpoint`). Nodes
/// never checkpointed yet are filled with the init params.
fn write_checkpoint<P: SlotPayload>(
    path: &Path,
    slots: &[WorkerSlot],
    n: usize,
    lanes: usize,
    p0: &[f32],
) {
    let mut init = vec![0.0f32; lanes];
    P::encode(p0, 1.0, &mut init);
    let mut flat = vec![0.0f32; n * lanes];
    for node in 0..n {
        flat[node * lanes..(node + 1) * lanes].copy_from_slice(&init);
    }
    for s in slots {
        for e in &s.checkpoint {
            let ix = e.node as usize;
            if ix < n && e.lanes.len() == lanes {
                flat[ix * lanes..(ix + 1) * lanes].copy_from_slice(&e.lanes);
            }
        }
    }
    if let Err(e) = save_npy(path, &flat) {
        obs::log::error("cluster", format_args!("checkpoint write failed: {e}"));
    }
}
