//! `--executor cluster` — the coordinator/worker cluster executor that
//! makes the wire real.
//!
//! The serial/parallel executors *simulate* the network through
//! [`CostModel`](crate::netmodel::CostModel); the freerun executor makes
//! contention and staleness real but keeps everything in one address
//! space. This module is the last step: separate OS processes gossiping
//! `WireCodec`-encoded model payloads over `std::net::TcpStream`, so
//! "bits on the wire" is measured from the socket, not modeled.
//!
//! Topology of a run:
//!
//! * one **coordinator** (`--role coordinator --listen ADDR`): registers
//!   `workers` workers, assigns each a node shard, ships the full
//!   [`RunConfig`](crate::config::RunConfig) as INI text, aggregates
//!   streamed progress, persists checkpoints, detects dead workers by
//!   heartbeat timeout and reassigns their shard, prints the final report;
//! * `workers` **workers** (`--role worker --connect ADDR`): run the
//!   freerun protocol over their shard, with cross-shard gossip over a
//!   full TCP mesh (hand-rolled length-prefixed, versioned, checksummed
//!   frames — see [`proto`]; zero new dependencies).
//!
//! The executor is throughput-faithful and non-replayable, like freerun:
//! assertions about it must be statistical (convergence bands, counter
//! positivity), never bit-exact.

pub mod coordinator;
pub mod proto;
pub mod transport;
pub mod worker;

pub use coordinator::{run_coordinator, ClusterReport};
pub use worker::run_worker;

use crate::cli::Cli;
use crate::config::RunConfig;

/// Which side of the cluster this process is.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Role {
    /// `--role coordinator --listen ADDR`
    Coordinator { listen: String },
    /// `--role worker --connect ADDR`
    Worker { connect: String },
}

/// Validated cluster-mode options parsed off the command line.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterOpts {
    pub role: Role,
    /// per-interaction worker sleep in µs (a test/debug knob: slows the
    /// run down enough that mid-run failures are injectable)
    pub throttle_us: u64,
    /// where the coordinator writes `cluster_ckpt.npy` / `cluster_final.npy`
    pub checkpoint_dir: std::path::PathBuf,
}

/// Parse and validate the cluster flags against the run config, mirroring
/// the style of the config-side validators (reject early, name the flag,
/// say what was expected). Returns `Ok(None)` when the run is not a
/// cluster run and no cluster flag was passed.
pub fn from_cli(cli: &Cli, cfg: &RunConfig) -> Result<Option<ClusterOpts>, String> {
    let is_cluster = cfg.executor == "cluster";
    let role = cli.get("role");
    if !is_cluster {
        if let Some(r) = role {
            return Err(format!(
                "--role {r} only applies to --executor cluster (got executor '{}')",
                cfg.executor
            ));
        }
        for flag in ["listen", "connect", "throttle-us", "checkpoint-dir"] {
            if cli.has(flag) {
                return Err(format!(
                    "--{flag} only applies to --executor cluster (got executor '{}')",
                    cfg.executor
                ));
            }
        }
        return Ok(None);
    }
    let role = match role {
        Some("coordinator") => {
            if cli.has("connect") {
                return Err("--connect is a worker flag; the coordinator takes --listen".into());
            }
            let listen = cli
                .get("listen")
                .ok_or("--role coordinator requires --listen HOST:PORT (PORT 0 = ephemeral)")?;
            Role::Coordinator { listen: listen.to_string() }
        }
        Some("worker") => {
            if cli.has("listen") {
                return Err("--listen is a coordinator flag; workers take --connect".into());
            }
            let connect = cli
                .get("connect")
                .ok_or("--role worker requires --connect HOST:PORT (the coordinator address)")?;
            Role::Worker { connect: connect.to_string() }
        }
        Some(other) => {
            return Err(format!("unknown --role '{other}' (expected coordinator|worker)"))
        }
        None => {
            return Err(
                "--executor cluster requires --role coordinator|worker: start one \
                 coordinator process (--role coordinator --listen HOST:PORT), then \
                 `workers` worker processes (--role worker --connect HOST:PORT)"
                    .into(),
            )
        }
    };
    let throttle_us = cli.parse_flag::<u64>("throttle-us")?.unwrap_or(0);
    let checkpoint_dir = match cli.get("checkpoint-dir") {
        Some(d) => std::path::PathBuf::from(d),
        None => std::env::temp_dir().join("swarm_cluster"),
    };
    Ok(Some(ClusterOpts { role, throttle_us, checkpoint_dir }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli(args: &[&str]) -> Cli {
        Cli::parse(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    fn cluster_cfg() -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.set("executor", "cluster").unwrap();
        cfg
    }

    #[test]
    fn non_cluster_run_without_flags_is_none() {
        let cfg = RunConfig::default();
        assert_eq!(from_cli(&cli(&["train"]), &cfg), Ok(None));
    }

    #[test]
    fn role_without_cluster_executor_is_rejected() {
        let cfg = RunConfig::default(); // executor=serial
        let err = from_cli(&cli(&["train", "--role", "worker"]), &cfg).unwrap_err();
        assert!(err.contains("--executor cluster"), "unhelpful error: {err}");
        // stray address flags are caught too
        let err = from_cli(&cli(&["train", "--listen", "x:1"]), &cfg).unwrap_err();
        assert!(err.contains("--listen"), "unhelpful error: {err}");
    }

    #[test]
    fn cluster_without_role_is_rejected_with_a_recipe() {
        let err = from_cli(&cli(&["train"]), &cluster_cfg()).unwrap_err();
        assert!(err.contains("--role coordinator|worker"), "unhelpful error: {err}");
    }

    #[test]
    fn coordinator_requires_listen_and_rejects_connect() {
        let c = cluster_cfg();
        let err = from_cli(&cli(&["train", "--role", "coordinator"]), &c).unwrap_err();
        assert!(err.contains("--listen"), "unhelpful error: {err}");
        let err = from_cli(
            &cli(&["train", "--role", "coordinator", "--connect", "h:1"]),
            &c,
        )
        .unwrap_err();
        assert!(err.contains("--connect is a worker flag"), "unhelpful error: {err}");
        let opts = from_cli(
            &cli(&["train", "--role", "coordinator", "--listen", "127.0.0.1:0"]),
            &c,
        )
        .unwrap()
        .unwrap();
        assert_eq!(opts.role, Role::Coordinator { listen: "127.0.0.1:0".into() });
    }

    #[test]
    fn worker_requires_connect_and_rejects_listen() {
        let c = cluster_cfg();
        let err = from_cli(&cli(&["train", "--role", "worker"]), &c).unwrap_err();
        assert!(err.contains("--connect"), "unhelpful error: {err}");
        let err =
            from_cli(&cli(&["train", "--role", "worker", "--listen", "h:1"]), &c).unwrap_err();
        assert!(err.contains("--listen is a coordinator flag"), "unhelpful error: {err}");
        let opts = from_cli(
            &cli(&["train", "--role", "worker", "--connect", "127.0.0.1:9"]),
            &c,
        )
        .unwrap()
        .unwrap();
        assert_eq!(opts.role, Role::Worker { connect: "127.0.0.1:9".into() });
        assert_eq!(opts.throttle_us, 0);
    }

    #[test]
    fn unknown_role_and_bad_throttle_are_rejected() {
        let c = cluster_cfg();
        let err = from_cli(&cli(&["train", "--role", "boss"]), &c).unwrap_err();
        assert!(err.contains("coordinator|worker"), "unhelpful error: {err}");
        let err = from_cli(
            &cli(&["train", "--role", "worker", "--connect", "h:1", "--throttle-us", "xyz"]),
            &c,
        )
        .unwrap_err();
        assert!(err.contains("throttle-us"), "unhelpful error: {err}");
    }
}
