//! The cluster worker process: the freerun loop over a node shard, with
//! cross-shard gossip over real sockets.
//!
//! A worker holds a [`ModelSlot`] for **all** `n` nodes — its own shard's
//! slots are authoritative, the rest are *mirrors* of the owning peers'
//! latest broadcasts. The compute loop is the freerun protocol verbatim
//! (ring → own-slot sync → local phase → partner snapshot → `MixPolicy::
//! merge` → publish + best-effort cross-write); it cannot tell whether a
//! partner is local or remote, because both are just slots. The only
//! difference is what happens *after* a publish:
//!
//! * a **dirty flag** marks the node; a dedicated sender thread picks it
//!   up, encodes the slot's latest payload once
//!   ([`WireCodec`](crate::coordinator::WireCodec) — the lattice codec
//!   finally encodes onto a real wire), and broadcasts it to
//!   every peer. The flag is latest-wins: if the compute loop publishes
//!   three times before the sender gets there, one frame ships carrying
//!   the newest payload — the double-buffered non-blocking outbound of the
//!   paper's communication model (compute never waits for the network);
//! * a **cross-write to a remote partner** becomes a `Cross` frame to the
//!   owner, applied there via `try_publish` — dropped and counted on
//!   conflict, exactly like the in-process path.
//!
//! # Lattice reference consistency
//!
//! Lattice decoding needs a reference both ends agree on. The wire
//! invariant: a node's mirror on every peer always holds the sender's
//! *previous broadcast* (TCP orders frames; `Publish` is broadcast to all
//! peers; only `Publish` frames write mirrors). So the sender encodes
//! against its own record of that broadcast (`last_pub`), self-decodes to
//! stay exact, and receivers decode against their mirror. First publishes,
//! decode-distance failures, and adoption hand-offs fall back to f32
//! (counted), which resets every replica of the reference; a periodic f32
//! refresh bounds any divergence window.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use super::proto::{Msg, NodeLanes, PayloadEnc, ProgressBody};
use super::transport::{connect_with_retry, send_msg, FrameConn};
use crate::backend::{build_backend, Backend};
use crate::config::RunConfig;
use crate::coordinator::freerun::ModelSlot;
use crate::coordinator::{
    make_algorithm, AlgoOptions, Algorithm, MergeScratch, MixPolicy, NodeState, PayloadKind,
    PlainModel, PushSumWeighted, SlotPayload, StalenessHistogram, StepCtx,
};
use crate::obs::{self, Sampler, SpanKind, TraceDrain, TraceRing};
use crate::quant::{self, QuantizedMsg};
use crate::rngx::Pcg64;
use crate::scenario::Scenario;

/// Stream tags for the cluster executor's sub-RNGs (disjoint from the
/// serial/parallel/freerun tags).
const STREAM_NODE_BASE: u64 = 0x5EED_C1A5_0000_1000;
const STREAM_WORKER_BASE: u64 = 0x5EED_C1A5_0000_0010;

/// Heartbeat cadence — must be comfortably inside any sane
/// `heartbeat_timeout` (validation floors the timeout at > 0; default 5s).
const PROGRESS_EVERY: Duration = Duration::from_millis(200);
/// Checkpoint cadence (the coordinator's recovery granularity).
const CHECKPOINT_EVERY: Duration = Duration::from_millis(400);
/// Every k-th broadcast of a node ships f32 even under the lattice codec —
/// bounds the divergence window if a receiver ever dropped a frame.
const F32_REFRESH_EVERY: u64 = 64;

/// Cross-thread counters streamed to the coordinator as [`ProgressBody`].
#[derive(Default)]
struct Counters {
    events: AtomicU64,
    steps: AtomicU64,
    wire_bits: AtomicU64,
    wire_fallbacks: AtomicU64,
    read_retries: AtomicU64,
    publish_retries: AtomicU64,
    push_conflicts: AtomicU64,
    busy_us: AtomicU64,
    wait_us: AtomicU64,
}

impl Counters {
    fn snapshot(&self) -> ProgressBody {
        ProgressBody {
            events: self.events.load(Ordering::Relaxed),
            steps: self.steps.load(Ordering::Relaxed),
            wire_bits: self.wire_bits.load(Ordering::Relaxed),
            wire_fallbacks: self.wire_fallbacks.load(Ordering::Relaxed),
            read_retries: self.read_retries.load(Ordering::Relaxed),
            publish_retries: self.publish_retries.load(Ordering::Relaxed),
            push_conflicts: self.push_conflicts.load(Ordering::Relaxed),
            busy_us: self.busy_us.load(Ordering::Relaxed),
            wait_us: self.wait_us.load(Ordering::Relaxed),
        }
    }
}

/// State shared between the compute loop, the sender, and the receiver
/// threads of one worker process.
struct Shared<P: SlotPayload> {
    /// slots for ALL n nodes: owned shard + mirrors of every peer's nodes
    slots: Vec<ModelSlot<P>>,
    /// current owner rank of each node (updated on `Adopt` broadcasts)
    owner: Vec<AtomicU32>,
    /// owned nodes whose slot changed since the sender's last broadcast
    dirty: Vec<AtomicBool>,
    /// local interaction count — the staleness/stamp clock of this process
    done: AtomicU64,
    stop: AtomicBool,
    counters: Counters,
    /// one ring shared by the compute, sender, and receiver threads (the
    /// concurrent-writer case the slot layout is designed for); capacity 0
    /// (no `--trace-out`) disables it
    trace: TraceRing,
    rank: u32,
    dim: usize,
}

/// Run one worker process: register with the coordinator at `connect`,
/// receive the shard assignment + run config, gossip until `Shutdown`.
/// `throttle_us` adds a per-interaction sleep (a debug/test knob that makes
/// mid-run failures injectable before the job drains).
pub fn run_worker(connect: &str, throttle_us: u64) -> Result<(), String> {
    let io = |e: std::io::Error| format!("cluster worker: {e}");
    // gossip listener first, so the Hello can advertise its port
    let listener = TcpListener::bind("127.0.0.1:0").map_err(io)?;
    let gossip_port = listener.local_addr().map_err(io)?.port();

    let coord = connect_with_retry(connect, Duration::from_secs(10)).map_err(io)?;
    let mut coord_writer = coord.try_clone().map_err(io)?;
    send_msg(&mut coord_writer, &Msg::Hello { gossip_port }).map_err(io)?;
    let mut coord_conn = FrameConn::new(coord);
    let assign = coord_conn
        .read_msg()
        .map_err(io)?
        .ok_or("cluster worker: coordinator closed before assigning a shard")?;
    let (rank, workers, config_ini, owned, peers) = match assign {
        Msg::Assign { rank, workers, config_ini, owned, peers } => {
            (rank, workers, config_ini, owned, peers)
        }
        m => return Err(format!("cluster worker: expected Assign, got {m:?}")),
    };
    let cfg = RunConfig::from_ini(&config_ini)
        .map_err(|e| format!("cluster worker: bad config from coordinator: {e}"))?;
    // the shipped config carries the coordinator's --log-level
    obs::log::set_level(obs::log::Level::parse(&cfg.log_level)?);
    obs::log::info(
        "cluster",
        format_args!(
            "worker {rank}/{workers}: {} node(s) of n={} (algorithm={}, wire={})",
            owned.len(),
            cfg.n,
            cfg.algo,
            cfg.wire
        ),
    );

    let algo = make_algorithm(
        &cfg.algo,
        &AlgoOptions {
            local_steps: cfg.local_steps(),
            mode: cfg.averaging_mode()?,
            h_localsgd: cfg.h.round().max(0.0) as u64,
            wire: cfg.wire_codec()?,
            kernel: cfg.kernel_enum()?,
        },
    )?;
    let policy = algo.mix_policy().ok_or_else(|| {
        format!(
            "cluster worker: algorithm '{}' has no free-running MixPolicy \
             (the coordinator should have rejected this job)",
            cfg.algo
        )
    })?;
    let backend = build_backend(&cfg)?;

    // full-mesh gossip: dial every lower rank, accept every higher rank.
    // Each connection splits into a read half (a FrameConn that keeps any
    // decoder state from the handshake — discarding it could drop or shear
    // a frame the peer sent right behind its PeerHello) and a write half.
    let mut peer_readers: Vec<Option<FrameConn>> = (0..workers).map(|_| None).collect();
    let mut peer_writers: Vec<Option<TcpStream>> = (0..workers).map(|_| None).collect();
    // gossip writes are best-effort: a short write timeout keeps a frozen
    // peer (full TCP buffer, stopped process) from stalling the sender
    // thread — a timed-out write drops the peer, and the coordinator's
    // heartbeat scan owns declaring it dead
    const GOSSIP_WRITE_TIMEOUT: Duration = Duration::from_millis(250);
    for p in &peers {
        if p.rank < rank {
            let mut s = connect_with_retry(&p.addr, Duration::from_secs(10)).map_err(io)?;
            s.set_write_timeout(Some(GOSSIP_WRITE_TIMEOUT)).map_err(io)?;
            send_msg(&mut s, &Msg::PeerHello { rank }).map_err(io)?;
            peer_readers[p.rank as usize] = Some(FrameConn::new(s.try_clone().map_err(io)?));
            peer_writers[p.rank as usize] = Some(s);
        }
    }
    let expect_accepts = peers.iter().filter(|p| p.rank > rank).count();
    let deadline = Instant::now() + Duration::from_secs(30);
    listener.set_nonblocking(true).map_err(io)?;
    let mut accepted = 0;
    while accepted < expect_accepts {
        match listener.accept() {
            Ok((s, _)) => {
                s.set_nodelay(true).ok();
                let mut conn = FrameConn::new(s);
                match conn.read_msg().map_err(io)? {
                    Some(Msg::PeerHello { rank: r }) if (r as usize) < peer_writers.len() => {
                        let w = conn.stream.try_clone().map_err(io)?;
                        w.set_write_timeout(Some(GOSSIP_WRITE_TIMEOUT)).map_err(io)?;
                        peer_writers[r as usize] = Some(w);
                        peer_readers[r as usize] = Some(conn);
                        accepted += 1;
                    }
                    m => return Err(format!("cluster worker: bad gossip handshake: {m:?}")),
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() > deadline {
                    return Err(format!(
                        "cluster worker {rank}: only {accepted}/{expect_accepts} peers \
                         connected within 30s"
                    ));
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(io(e)),
        }
    }

    match policy.payload() {
        PayloadKind::Plain => worker_with::<PlainModel>(
            &cfg,
            algo.as_ref(),
            policy.as_ref(),
            backend.as_ref(),
            rank,
            workers,
            &owned,
            peer_readers,
            peer_writers,
            coord_conn,
            coord_writer,
            throttle_us,
        ),
        PayloadKind::PushSumWeighted => worker_with::<PushSumWeighted>(
            &cfg,
            algo.as_ref(),
            policy.as_ref(),
            backend.as_ref(),
            rank,
            workers,
            &owned,
            peer_readers,
            peer_writers,
            coord_conn,
            coord_writer,
            throttle_us,
        ),
    }
}

/// Decode lanes arriving in an `Adopt`/checkpoint entry back into a fresh
/// node state (push-sum restores the weight lane; momentum restarts cold).
fn state_from_lanes<P: SlotPayload>(
    lanes: &[f32],
    dim: usize,
    node: usize,
    seed: u64,
) -> NodeState {
    let mut st = NodeState::new(
        lanes[..dim].to_vec(),
        vec![0.0; dim],
        Pcg64::stream(seed, STREAM_NODE_BASE + node as u64),
    );
    if P::AUX_LANES == 1 {
        st.weight = lanes[dim] as f64;
    }
    st
}

#[allow(clippy::too_many_arguments)]
fn worker_with<P: SlotPayload>(
    cfg: &RunConfig,
    algo: &dyn Algorithm,
    policy: &dyn MixPolicy,
    backend: &dyn Backend,
    rank: u32,
    workers: u32,
    owned: &[u32],
    peer_readers: Vec<Option<FrameConn>>,
    peer_writers: Vec<Option<TcpStream>>,
    coord_conn: FrameConn,
    coord_writer: TcpStream,
    throttle_us: u64,
) -> Result<(), String> {
    let n = cfg.n;
    let dim = backend.dim();
    let (p0, m0) = backend.init();
    // every rank resolves the identical scenario from the shipped config
    // (same seed → same graph stages and per-node rates on all processes)
    let scn = Scenario::from_config(cfg)?;
    let obs_opts = cfg.obs_options();

    let sh = Arc::new(Shared::<P> {
        slots: (0..n).map(|_| ModelSlot::<P>::new(&p0)).collect(),
        owner: (0..n).map(|k| AtomicU32::new(k as u32 % workers)).collect(),
        dirty: (0..n).map(|_| AtomicBool::new(false)).collect(),
        done: AtomicU64::new(0),
        stop: AtomicBool::new(false),
        counters: Counters::default(),
        trace: TraceRing::new(obs_opts.trace_capacity),
        rank,
        dim,
    });

    let (cross_tx, cross_rx) = mpsc::channel::<(u32, Vec<f32>)>();
    let (adopt_tx, adopt_rx) = mpsc::channel::<Vec<NodeLanes>>();
    let (pong_tx, pong_rx) = mpsc::channel::<u64>();
    let (final_tx, final_rx) = mpsc::channel::<Msg>();

    // coordinator reader: owner-map updates on Adopt, stop on Shutdown.
    // Detached by design — it blocks in read and dies with the process.
    {
        let sh = Arc::clone(&sh);
        let mut conn = coord_conn;
        std::thread::spawn(move || loop {
            match conn.read_msg() {
                Ok(Some(Msg::Adopt { to_rank, entries, .. })) => {
                    for e in &entries {
                        sh.owner[e.node as usize].store(to_rank, Ordering::Release);
                    }
                    if to_rank == sh.rank {
                        let _ = adopt_tx.send(entries);
                    }
                }
                Ok(Some(Msg::Shutdown { .. })) | Ok(None) => {
                    sh.stop.store(true, Ordering::Release);
                    return;
                }
                // RTT probe: the sender thread echoes the timestamp back
                Ok(Some(Msg::Ping { t_ns })) => {
                    let _ = pong_tx.send(t_ns);
                }
                Ok(Some(_)) => {}
                Err(_) => {
                    sh.stop.store(true, Ordering::Release);
                    return;
                }
            }
        });
    }

    // one receiver thread per peer connection (also detached)
    for (peer, conn) in peer_readers.into_iter().enumerate() {
        let Some(conn) = conn else { continue };
        let sh = Arc::clone(&sh);
        std::thread::spawn(move || receive_loop::<P>(sh, conn, peer));
    }

    // the sender thread owns every outbound socket
    let sender = {
        let sh = Arc::clone(&sh);
        let codec = policy.wire();
        std::thread::spawn(move || {
            send_loop::<P>(sh, peer_writers, coord_writer, codec, cross_rx, pong_rx, final_rx)
        })
    };

    // ---- the compute loop: freerun's worker protocol over the shard ----
    let lr = cfg.lr_schedule_enum()?;
    let cost = cfg.cost_model();
    let mut states: Vec<(usize, NodeState)> = owned
        .iter()
        .map(|&k| {
            let st = NodeState::new(
                p0.clone(),
                m0.clone(),
                Pcg64::stream(cfg.seed, STREAM_NODE_BASE + k as u64),
            );
            (k as usize, st)
        })
        .collect();
    let mut wrng = Pcg64::stream(cfg.seed, STREAM_WORKER_BASE + rank as u64);
    let mut heap: std::collections::BinaryHeap<std::cmp::Reverse<(u64, usize)>> =
        std::collections::BinaryHeap::new();
    // integer clock keys (exponential times scaled to µ-ticks) keep the
    // heap Ord without the f64 wrapper; each node's clock runs at its
    // scenario rate (1.0 under uniform speeds)
    let clock = |r: &mut Pcg64, rate: f64| (r.exponential(rate) * 1e6) as u64;
    for ix in 0..states.len() {
        let at = clock(&mut wrng, scn.rate(states[ix].0));
        heap.push(std::cmp::Reverse((at, ix)));
    }
    let lanes = P::lanes(dim);
    let mut scratch = MergeScratch::with_kernel(lanes, algo.kernel());
    let mut staleness = StalenessHistogram::new((8 * n).max(1024));
    let sync_own = policy.needs_own_slot_sync();
    let mut local_events = 0u64;
    let tracing = sh.trace.enabled();
    let mut sampler = Sampler::new(obs_opts.sample_rate(), cfg.seed.wrapping_add(rank as u64));

    while !sh.stop.load(Ordering::Acquire) {
        // integrate adopted nodes (dead peer's shard, from the coordinator)
        while let Ok(entries) = adopt_rx.try_recv() {
            let base = heap.peek().map(|std::cmp::Reverse((at, _))| *at).unwrap_or(0);
            for e in entries {
                let node = e.node as usize;
                let st = state_from_lanes::<P>(&e.lanes, dim, node, cfg.seed);
                sh.slots[node].publish(&e.lanes, sh.done.load(Ordering::Relaxed));
                sh.dirty[node].store(true, Ordering::Release);
                let ix = states.len();
                states.push((node, st));
                heap.push(std::cmp::Reverse((base + clock(&mut wrng, scn.rate(node)), ix)));
                obs::log::info("cluster", format_args!("worker {rank}: adopted node {node}"));
            }
        }
        let Some(std::cmp::Reverse((at, ix))) = heap.pop() else {
            std::thread::sleep(Duration::from_millis(2));
            continue;
        };
        let started = Instant::now();
        let traced = tracing && sampler.hit();
        let mut sync_secs = 0.0f64;
        let (node, st) = &mut states[ix];
        let node = *node;
        if sync_own {
            let t0 = Instant::now();
            let (_, r) = sh.slots[node].read_into(&mut scratch.own);
            sync_secs += t0.elapsed().as_secs_f64();
            sh.counters.read_retries.fetch_add(r, Ordering::Relaxed);
            policy.absorb_own_slot(st, &scratch.own, dim);
        }
        // the lr schedule and the scenario's graph stages want a global
        // event index; without a global counter, rank-striped local counts
        // are an unbiased monotone proxy
        let t_global = local_events * workers as u64 + rank as u64;
        let graph = scn.graph_at(t_global);
        let partner = graph.sample_neighbor(node, &mut wrng);
        let h = policy.draw_steps(&mut wrng);
        let ctx = StepCtx { backend, cost: &cost, graph, lr: lr.at(t_global + 1), dim, n };
        let tc = if traced { sh.trace.now_ns() } else { 0 };
        policy.local_phase(&ctx, node, st, h);
        if traced {
            sh.trace.span(SpanKind::Compute, rank, tc, h);
        }
        sh.counters.steps.fetch_add(h, Ordering::Relaxed);
        // partner snapshot: a local slot or a peer mirror — same read
        let t0 = Instant::now();
        let (stamp, r) = sh.slots[partner].read_into(&mut scratch.snapshot);
        sync_secs += t0.elapsed().as_secs_f64();
        sh.counters.read_retries.fetch_add(r, Ordering::Relaxed);
        if traced && r > 0 {
            let t = sh.trace.now_ns();
            sh.trace.record(SpanKind::SlotRetry, rank, t, 0, r);
        }
        staleness.record(sh.done.load(Ordering::Relaxed).saturating_sub(stamp));
        // merge accounting note: the policy's EventOutcome models the
        // simulated wire; the cluster reports *real* socket bytes instead,
        // so only the fallback count is taken from the outcome here
        let tm = if traced { sh.trace.now_ns() } else { 0 };
        let outcome = policy.merge(&ctx, node, st, &mut scratch, &mut wrng);
        if traced {
            sh.trace.span(SpanKind::Merge, rank, tm, outcome.fallbacks);
        }
        if outcome.fallbacks > 0 {
            sh.counters.wire_fallbacks.fetch_add(outcome.fallbacks, Ordering::Relaxed);
        }
        st.interactions += 1;
        let stamp_now = sh.done.load(Ordering::Relaxed);
        let tp = if traced { sh.trace.now_ns() } else { 0 };
        let t1 = Instant::now();
        let pub_retries = sh.slots[node].publish(&scratch.publish, stamp_now);
        sh.counters.publish_retries.fetch_add(pub_retries, Ordering::Relaxed);
        sh.dirty[node].store(true, Ordering::Release);
        let p_owner = sh.owner[partner].load(Ordering::Acquire);
        if p_owner == rank {
            if !sh.slots[partner].try_publish(&scratch.cross, stamp_now) {
                sh.counters.push_conflicts.fetch_add(1, Ordering::Relaxed);
            }
            sh.dirty[partner].store(true, Ordering::Release);
        } else {
            // remote partner: the cross-write crosses the wire instead
            let _ = cross_tx.send((partner as u32, scratch.cross.clone()));
        }
        sync_secs += t1.elapsed().as_secs_f64();
        if traced {
            sh.trace.span(SpanKind::Publish, rank, tp, partner as u64);
            if pub_retries > 0 {
                let t = sh.trace.now_ns();
                sh.trace.record(SpanKind::SlotRetry, rank, t, 0, pub_retries);
            }
        }
        heap.push(std::cmp::Reverse((at + clock(&mut wrng, scn.rate(node)), ix)));
        local_events += 1;
        sh.done.fetch_add(1, Ordering::Release);
        sh.counters.events.fetch_add(1, Ordering::Relaxed);
        let dt = started.elapsed().as_secs_f64();
        let busy = ((dt - sync_secs).max(0.0) * 1e6) as u64;
        sh.counters.busy_us.fetch_add(busy, Ordering::Relaxed);
        sh.counters.wait_us.fetch_add((sync_secs * 1e6) as u64, Ordering::Relaxed);
        if throttle_us > 0 {
            std::thread::sleep(Duration::from_micros(throttle_us));
        }
    }

    // final report: every owned slot's latest payload + counters + staleness
    let mut entries = Vec::new();
    let mut buf = vec![0.0f32; lanes];
    for &(node, _) in &states {
        if sh.owner[node].load(Ordering::Acquire) == rank {
            sh.slots[node].read_into(&mut buf);
            entries.push(NodeLanes { node: node as u32, lanes: buf.clone() });
        }
    }
    let done_msg = Msg::done(entries, sh.counters.snapshot(), &staleness);
    final_tx
        .send(done_msg)
        .map_err(|_| "cluster worker: sender thread died before the final report".to_string())?;
    sender
        .join()
        .map_err(|_| "cluster worker: sender thread panicked".to_string())?
        .map_err(|e| format!("cluster worker: {e}"))?;
    if !cfg.trace_out.is_empty() && sh.trace.enabled() {
        let drain = TraceDrain::from_rings([&sh.trace]);
        let path = rank_trace_path(&cfg.trace_out, rank);
        match std::fs::write(&path, drain.to_chrome_json()) {
            Ok(()) => obs::log::info(
                "cluster",
                format_args!(
                    "worker {rank}: trace written to {path} ({} events, {} dropped)",
                    drain.events.len(),
                    drain.dropped
                ),
            ),
            Err(e) => {
                obs::log::warn("cluster", format_args!("worker {rank}: trace write failed: {e}"))
            }
        }
    }
    obs::log::info("cluster", format_args!("worker {rank}: done ({local_events} interactions)"));
    Ok(())
}

/// `--trace-out trace.json` on a cluster worker becomes
/// `trace.rank<R>.json`, so concurrent ranks don't clobber one file.
fn rank_trace_path(path: &str, rank: u32) -> String {
    match path.rsplit_once('.') {
        Some((stem, ext)) if !ext.contains('/') => format!("{stem}.rank{rank}.{ext}"),
        _ => format!("{path}.rank{rank}"),
    }
}

/// Receiver thread for one peer connection: peers' `Publish` broadcasts
/// land in mirror slots (lattice frames decoded against the mirror — the
/// previous broadcast), `Cross` frames are best-effort applied to owned
/// slots. Exits on EOF/socket error (peer death is the coordinator's
/// problem, not ours).
fn receive_loop<P: SlotPayload>(sh: Arc<Shared<P>>, mut conn: FrameConn, _peer: usize) {
    let dim = sh.dim;
    let lanes = P::lanes(dim);
    let tracing = sh.trace.enabled();
    let mut refbuf = vec![0.0f32; lanes];
    loop {
        let msg = match conn.read_msg() {
            Ok(Some(m)) => m,
            Ok(None) | Err(_) => return,
        };
        match msg {
            Msg::Publish { node, enc } => {
                if tracing {
                    let bytes = match &enc {
                        PayloadEnc::F32 { lanes } => 4 * lanes.len() as u64,
                        PayloadEnc::Lattice { packed, aux, .. } => {
                            (packed.len() + 4 * aux.len()) as u64
                        }
                    };
                    let t = sh.trace.now_ns();
                    sh.trace.record(SpanKind::GossipRx, sh.rank, t, 0, bytes);
                }
                let node = node as usize;
                if node >= sh.slots.len() || sh.owner[node].load(Ordering::Acquire) == sh.rank {
                    continue; // stale broadcast across an adoption hand-off
                }
                let stamp = sh.done.load(Ordering::Relaxed);
                match enc {
                    PayloadEnc::F32 { lanes: data } => {
                        if data.len() == lanes {
                            sh.slots[node].publish(&data, stamp);
                        }
                    }
                    PayloadEnc::Lattice { bits, eps, seed, len, checksum, packed, aux } => {
                        if len as usize != dim || aux.len() != lanes - dim {
                            sh.counters.wire_fallbacks.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                        sh.slots[node].read_into(&mut refbuf);
                        let msg = QuantizedMsg {
                            bits,
                            eps,
                            seed,
                            len: len as usize,
                            payload: packed,
                            checksum,
                        };
                        match quant::decode(&msg, &refbuf[..dim]) {
                            Ok(mut decoded) => {
                                decoded.extend_from_slice(&aux);
                                sh.slots[node].publish(&decoded, stamp);
                            }
                            Err(_) => {
                                // reference diverged: drop, count, wait for
                                // the sender's periodic f32 refresh
                                sh.counters.wire_fallbacks.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                }
            }
            Msg::Cross { node, lanes: data } => {
                if tracing {
                    let t = sh.trace.now_ns();
                    sh.trace.record(SpanKind::GossipRx, sh.rank, t, 0, 4 * data.len() as u64);
                }
                let node = node as usize;
                if node >= sh.slots.len()
                    || sh.owner[node].load(Ordering::Acquire) != sh.rank
                    || data.len() != lanes
                {
                    continue; // raced an adoption; best-effort semantics
                }
                let stamp = sh.done.load(Ordering::Relaxed);
                if !sh.slots[node].try_publish(&data, stamp) {
                    sh.counters.push_conflicts.fetch_add(1, Ordering::Relaxed);
                } else {
                    sh.dirty[node].store(true, Ordering::Release);
                }
            }
            _ => { /* peers only gossip */ }
        }
    }
}

/// The sender thread: owns every outbound socket. Scans dirty flags
/// (latest-wins outbound snapshots), encodes each publish **once** against
/// `last_pub`, broadcasts to all live peers, forwards queued cross-writes,
/// heartbeats `Progress`, streams `Checkpoint`s, and finally forwards the
/// compute loop's `Done`.
fn send_loop<P: SlotPayload>(
    sh: Arc<Shared<P>>,
    mut peers: Vec<Option<TcpStream>>,
    mut coord: TcpStream,
    codec: crate::coordinator::WireCodec,
    cross_rx: mpsc::Receiver<(u32, Vec<f32>)>,
    pong_rx: mpsc::Receiver<u64>,
    final_rx: mpsc::Receiver<Msg>,
) -> std::io::Result<()> {
    let dim = sh.dim;
    let lanes = P::lanes(dim);
    let mut buf = vec![0.0f32; lanes];
    // the sender's record of each node's previous broadcast, as decoded by
    // every receiver — the lattice reference (None → f32 resync)
    let mut last_pub: Vec<Option<Vec<f32>>> = vec![None; sh.slots.len()];
    let mut pub_seq: Vec<u64> = vec![0; sh.slots.len()];
    let mut hb = Instant::now();
    let mut cp = Instant::now();
    let n = sh.slots.len();

    let broadcast = |peers: &mut Vec<Option<TcpStream>>, sh: &Shared<P>, msg: &Msg| {
        for slot in peers.iter_mut() {
            if let Some(s) = slot {
                let t0 = if sh.trace.enabled() { sh.trace.now_ns() } else { 0 };
                match send_msg(s, msg) {
                    Ok(b) => {
                        sh.counters.wire_bits.fetch_add(8 * b as u64, Ordering::Relaxed);
                        if sh.trace.enabled() {
                            sh.trace.span(SpanKind::GossipTx, sh.rank, t0, b as u64);
                        }
                    }
                    Err(_) => *slot = None, // dead peer; coordinator recovers
                }
            }
        }
    };

    loop {
        let mut idle = true;
        // the compute loop's final report ends this thread
        if let Ok(done) = final_rx.try_recv() {
            send_msg(&mut coord, &done)?;
            return Ok(());
        }
        // heartbeat-RTT probes: echo the coordinator's timestamp verbatim
        while let Ok(t_ns) = pong_rx.try_recv() {
            idle = false;
            send_msg(&mut coord, &Msg::Pong { t_ns })?;
        }
        // queued cross-writes to remote owners
        while let Ok((node, data)) = cross_rx.try_recv() {
            idle = false;
            let owner = sh.owner[node as usize].load(Ordering::Acquire) as usize;
            if owner < peers.len() {
                if let Some(s) = peers[owner].as_mut() {
                    let t0 = if sh.trace.enabled() { sh.trace.now_ns() } else { 0 };
                    match send_msg(s, &Msg::Cross { node, lanes: data }) {
                        Ok(b) => {
                            sh.counters.wire_bits.fetch_add(8 * b as u64, Ordering::Relaxed);
                            if sh.trace.enabled() {
                                sh.trace.span(SpanKind::GossipTx, sh.rank, t0, b as u64);
                            }
                        }
                        Err(_) => peers[owner] = None,
                    }
                }
            }
        }
        // latest-wins publish broadcast of every dirty owned node
        for node in 0..n {
            if sh.owner[node].load(Ordering::Acquire) != sh.rank {
                continue;
            }
            if !sh.dirty[node].swap(false, Ordering::AcqRel) {
                continue;
            }
            idle = false;
            sh.slots[node].read_into(&mut buf);
            pub_seq[node] += 1;
            let enc = encode_publish(codec, &buf, dim, &mut last_pub[node], pub_seq[node], &sh);
            broadcast(&mut peers, &sh, &Msg::Publish { node: node as u32, enc });
        }
        if hb.elapsed() >= PROGRESS_EVERY {
            hb = Instant::now();
            send_msg(&mut coord, &Msg::Progress(sh.counters.snapshot()))?;
            if sh.trace.enabled() {
                let t = sh.trace.now_ns();
                let ev = sh.counters.events.load(Ordering::Relaxed);
                sh.trace.record(SpanKind::Heartbeat, sh.rank, t, 0, ev);
            }
        }
        if cp.elapsed() >= CHECKPOINT_EVERY {
            cp = Instant::now();
            let mut entries = Vec::new();
            for node in 0..n {
                if sh.owner[node].load(Ordering::Acquire) == sh.rank {
                    sh.slots[node].read_into(&mut buf);
                    entries.push(NodeLanes { node: node as u32, lanes: buf.clone() });
                }
            }
            let events = sh.counters.events.load(Ordering::Relaxed);
            send_msg(&mut coord, &Msg::Checkpoint { events, entries })?;
        }
        if idle {
            std::thread::sleep(Duration::from_micros(500));
        }
    }
}

/// Encode one outbound publish, once, against the node's previous
/// broadcast. Falls back to f32 (resetting the shared reference) on first
/// publish, on the periodic refresh, and when the self-decode distance
/// criterion fails — the counted fallback path of the lattice scheme.
fn encode_publish<P: SlotPayload>(
    codec: crate::coordinator::WireCodec,
    buf: &[f32],
    dim: usize,
    last_pub: &mut Option<Vec<f32>>,
    seq: u64,
    sh: &Shared<P>,
) -> PayloadEnc {
    use crate::coordinator::WireCodec;
    let model = &buf[..dim];
    if let WireCodec::Lattice { bits, eps } = codec {
        if seq % F32_REFRESH_EVERY != 0 {
            if let Some(reference) = last_pub.as_deref() {
                let qm = quant::encode(model, eps, bits, seq as u32);
                match quant::decode(&qm, reference) {
                    Ok(decoded) => {
                        *last_pub = Some(decoded);
                        return PayloadEnc::Lattice {
                            bits,
                            eps,
                            seed: qm.seed,
                            len: qm.len as u32,
                            checksum: qm.checksum,
                            packed: qm.payload,
                            aux: buf[dim..].to_vec(),
                        };
                    }
                    Err(_) => {
                        sh.counters.wire_fallbacks.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
    }
    *last_pub = Some(model.to_vec());
    PayloadEnc::F32 { lanes: buf.to_vec() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_trace_path_inserts_before_the_extension() {
        assert_eq!(rank_trace_path("trace.json", 2), "trace.rank2.json");
        assert_eq!(rank_trace_path("out/t.json", 0), "out/t.rank0.json");
        assert_eq!(rank_trace_path("trace", 1), "trace.rank1");
        assert_eq!(rank_trace_path("out.d/trace", 3), "out.d/trace.rank3");
    }
}
