//! Wire protocol for the cluster executor: hand-rolled length-prefixed
//! binary framing (zero dependencies), versioned and checksummed.
//!
//! # Frame layout
//!
//! ```text
//! [0..4)    magic  b"SWRM"
//! [4..6)    protocol version, u16 LE   (PROTO_VERSION)
//! [6..7)    message kind, u8           (see the Msg enum)
//! [7..8)    reserved, 0
//! [8..12)   payload length, u32 LE
//! [12..12+len)       payload bytes
//! [12+len..20+len)   FNV-1a checksum over header + payload, u64 LE
//! ```
//!
//! The checksum is a transport-integrity guard (torn writes, crossed
//! streams), *not* cryptographic authentication — multi-host auth/TLS is
//! explicitly out of scope for the loopback MVP (see ROADMAP item 3).
//!
//! [`FrameDecoder`] is an incremental state machine fed arbitrary byte
//! chunks (whatever `read()` returned); it yields complete frames and
//! keeps partial ones buffered, so framing is testable without any
//! sockets. All integers are little-endian. Any [`FrameError`] is fatal
//! for the connection that produced it: the stream offset is unknowable
//! after corruption, so callers drop the peer rather than resync.

use crate::coordinator::StalenessHistogram;

/// Frame magic.
pub const MAGIC: [u8; 4] = *b"SWRM";
/// Protocol version; peers with a different version are rejected at the
/// first frame. v2 added the Ping/Pong heartbeat-RTT probes.
pub const PROTO_VERSION: u16 = 2;
/// Frame header length (magic + version + kind + reserved + payload len).
pub const HEADER_LEN: usize = 12;
/// Trailing checksum length.
pub const CHECKSUM_LEN: usize = 8;
/// Upper bound on one frame's payload — far above any real message (the
/// largest is a checkpoint of every node's lanes), so hitting it means a
/// corrupt or hostile length prefix, not a big model.
pub const MAX_PAYLOAD: usize = 1 << 26;

/// FNV-1a over `bytes` (same function as the checkpoint trailer's).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Why a byte stream stopped being a frame stream. All variants are fatal
/// for the connection (see the module docs).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FrameError {
    /// first four bytes were not [`MAGIC`]
    BadMagic,
    /// peer speaks a different protocol version
    VersionMismatch { got: u16 },
    /// length prefix exceeds [`MAX_PAYLOAD`]
    TooLarge { len: usize },
    /// frame checksum did not match its header + payload
    ChecksumMismatch,
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::BadMagic => write!(f, "bad frame magic (not a swarm cluster peer?)"),
            FrameError::VersionMismatch { got } => write!(
                f,
                "protocol version mismatch: peer speaks v{got}, this build v{PROTO_VERSION}"
            ),
            FrameError::TooLarge { len } => {
                write!(f, "frame length {len} exceeds the {MAX_PAYLOAD}-byte cap")
            }
            FrameError::ChecksumMismatch => write!(f, "frame checksum mismatch"),
        }
    }
}

impl std::error::Error for FrameError {}

/// One complete decoded frame: the raw kind byte plus its payload.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    pub kind: u8,
    pub payload: Vec<u8>,
}

/// Encode one frame (header + payload + checksum) ready for a socket
/// write.
pub fn encode_frame(kind: u8, payload: &[u8]) -> Vec<u8> {
    assert!(payload.len() <= MAX_PAYLOAD, "payload exceeds MAX_PAYLOAD");
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + CHECKSUM_LEN);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&PROTO_VERSION.to_le_bytes());
    out.push(kind);
    out.push(0);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    let sum = fnv1a(&out);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Incremental frame decoder: [`feed`](Self::feed) raw bytes in any
/// chunking, pull complete frames with [`next_frame`](Self::next_frame).
#[derive(Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    /// consumed prefix of `buf` (compacted lazily)
    off: usize,
}

impl FrameDecoder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append bytes read off the transport.
    pub fn feed(&mut self, bytes: &[u8]) {
        // compact before growing so the buffer stays bounded by one frame
        if self.off > 0 && (self.off >= self.buf.len() || self.off > MAX_PAYLOAD) {
            self.buf.drain(..self.off);
            self.off = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes currently buffered but not yet consumed as frames.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.off
    }

    /// Try to decode the next complete frame. `Ok(None)` means "need more
    /// bytes" (partial-read resumption); an `Err` is fatal for the
    /// connection.
    pub fn next_frame(&mut self) -> Result<Option<Frame>, FrameError> {
        let avail = &self.buf[self.off..];
        if avail.len() < HEADER_LEN {
            return Ok(None);
        }
        if avail[..4] != MAGIC {
            return Err(FrameError::BadMagic);
        }
        let version = u16::from_le_bytes([avail[4], avail[5]]);
        if version != PROTO_VERSION {
            return Err(FrameError::VersionMismatch { got: version });
        }
        let kind = avail[6];
        let len = u32::from_le_bytes([avail[8], avail[9], avail[10], avail[11]]) as usize;
        if len > MAX_PAYLOAD {
            return Err(FrameError::TooLarge { len });
        }
        let total = HEADER_LEN + len + CHECKSUM_LEN;
        if avail.len() < total {
            return Ok(None);
        }
        let body = &avail[..HEADER_LEN + len];
        let want = u64::from_le_bytes(avail[HEADER_LEN + len..total].try_into().unwrap());
        if fnv1a(body) != want {
            return Err(FrameError::ChecksumMismatch);
        }
        let payload = avail[HEADER_LEN..HEADER_LEN + len].to_vec();
        self.off += total;
        Ok(Some(Frame { kind, payload }))
    }
}

// ---------------------------------------------------------------------------
// payload (de)serialization helpers
// ---------------------------------------------------------------------------

struct Wr(Vec<u8>);

impl Wr {
    fn new() -> Self {
        Wr(Vec::new())
    }
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f32s(&mut self, vs: &[f32]) {
        self.u32(vs.len() as u32);
        for &v in vs {
            self.f32(v);
        }
    }
    fn u64s(&mut self, vs: &[u64]) {
        self.u32(vs.len() as u32);
        for &v in vs {
            self.u64(v);
        }
    }
    fn bytes(&mut self, vs: &[u8]) {
        self.u32(vs.len() as u32);
        self.0.extend_from_slice(vs);
    }
    fn str(&mut self, s: &str) {
        self.bytes(s.as_bytes());
    }
}

struct Rd<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Rd<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Rd { buf, off: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.off + n > self.buf.len() {
            return Err(format!(
                "message payload truncated: wanted {n} bytes at offset {}, have {}",
                self.off,
                self.buf.len()
            ));
        }
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16, String> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32, String> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn len_prefix(&mut self) -> Result<usize, String> {
        let n = self.u32()? as usize;
        // each element is at least one byte; an oversized count is a
        // protocol error, not an allocation request
        if n > self.buf.len() {
            return Err(format!("length prefix {n} exceeds the payload size"));
        }
        Ok(n)
    }
    fn f32s(&mut self) -> Result<Vec<f32>, String> {
        let n = self.len_prefix()?;
        (0..n).map(|_| self.f32()).collect()
    }
    fn u64s(&mut self) -> Result<Vec<u64>, String> {
        let n = self.len_prefix()?;
        (0..n).map(|_| self.u64()).collect()
    }
    fn bytes(&mut self) -> Result<Vec<u8>, String> {
        let n = self.len_prefix()?;
        Ok(self.take(n)?.to_vec())
    }
    fn str(&mut self) -> Result<String, String> {
        String::from_utf8(self.bytes()?).map_err(|_| "invalid utf-8 in string field".into())
    }
    fn done(&self) -> Result<(), String> {
        if self.off != self.buf.len() {
            return Err(format!("{} trailing bytes after message body", self.buf.len() - self.off));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// messages
// ---------------------------------------------------------------------------

/// One worker's gossip endpoint as the coordinator advertises it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PeerAddr {
    pub rank: u32,
    pub addr: String,
}

/// One node's payload lanes (checkpoint / adoption / final-state entries).
#[derive(Clone, Debug, PartialEq)]
pub struct NodeLanes {
    pub node: u32,
    pub lanes: Vec<f32>,
}

/// Scalar counters a worker streams to the coordinator on every heartbeat
/// — the wire form of the per-worker [`FreerunStats`] slice.
///
/// [`FreerunStats`]: crate::coordinator::FreerunStats
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ProgressBody {
    /// interactions this worker has initiated
    pub events: u64,
    /// local SGD steps performed
    pub steps: u64,
    /// bits this worker actually wrote to peer sockets (real bytes × 8)
    pub wire_bits: u64,
    /// lattice publishes that fell back to f32 + receiver-side decode drops
    pub wire_fallbacks: u64,
    pub read_retries: u64,
    pub publish_retries: u64,
    pub push_conflicts: u64,
    /// wall-clock busy/wait split, in microseconds
    pub busy_us: u64,
    pub wait_us: u64,
}

impl ProgressBody {
    fn write(&self, w: &mut Wr) {
        w.u64(self.events);
        w.u64(self.steps);
        w.u64(self.wire_bits);
        w.u64(self.wire_fallbacks);
        w.u64(self.read_retries);
        w.u64(self.publish_retries);
        w.u64(self.push_conflicts);
        w.u64(self.busy_us);
        w.u64(self.wait_us);
    }

    fn read(r: &mut Rd<'_>) -> Result<Self, String> {
        Ok(ProgressBody {
            events: r.u64()?,
            steps: r.u64()?,
            wire_bits: r.u64()?,
            wire_fallbacks: r.u64()?,
            read_retries: r.u64()?,
            publish_retries: r.u64()?,
            push_conflicts: r.u64()?,
            busy_us: r.u64()?,
            wait_us: r.u64()?,
        })
    }

    /// Field-wise sum (coordinator-side aggregation across workers).
    pub fn add(&mut self, o: &ProgressBody) {
        self.events += o.events;
        self.steps += o.steps;
        self.wire_bits += o.wire_bits;
        self.wire_fallbacks += o.wire_fallbacks;
        self.read_retries += o.read_retries;
        self.publish_retries += o.publish_retries;
        self.push_conflicts += o.push_conflicts;
        self.busy_us += o.busy_us;
        self.wait_us += o.wait_us;
    }
}

/// How one published payload crosses the wire: raw f32 lanes, or the
/// lattice codec's packed coordinates (model lanes) plus raw aux lanes
/// (push-sum weight). The lattice branch is [`crate::quant::encode_into`]
/// output verbatim — the coordinates the receiver decodes against its
/// mirror of the sender's previous broadcast.
#[derive(Clone, Debug, PartialEq)]
pub enum PayloadEnc {
    F32 { lanes: Vec<f32> },
    Lattice {
        bits: u32,
        eps: f32,
        seed: u32,
        len: u32,
        checksum: u64,
        packed: Vec<u8>,
        aux: Vec<f32>,
    },
}

fn write_node_lanes(w: &mut Wr, entries: &[NodeLanes]) {
    w.u32(entries.len() as u32);
    for e in entries {
        w.u32(e.node);
        w.f32s(&e.lanes);
    }
}

fn read_node_lanes(r: &mut Rd<'_>) -> Result<Vec<NodeLanes>, String> {
    let n = r.len_prefix()?;
    (0..n)
        .map(|_| Ok(NodeLanes { node: r.u32()?, lanes: r.f32s()? }))
        .collect()
}

/// Every message the cluster control and gossip planes exchange. Kind
/// bytes are part of the protocol; renumbering is a version bump.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// worker → coordinator, first frame: the port the worker's gossip
    /// listener bound
    Hello { gossip_port: u16 },
    /// coordinator → worker: rank, total worker count, the full run config
    /// (INI text), this worker's initial node shard, and every worker's
    /// gossip endpoint
    Assign { rank: u32, workers: u32, config_ini: String, owned: Vec<u32>, peers: Vec<PeerAddr> },
    /// worker → coordinator heartbeat + streamed stats
    Progress(ProgressBody),
    /// worker → coordinator: current payload lanes of its owned nodes (the
    /// recovery source), stamped with the worker's event count
    Checkpoint { events: u64, entries: Vec<NodeLanes> },
    /// coordinator → every worker: nodes of `from_rank` (declared dead)
    /// move to `to_rank`; entries carry the last-checkpoint lanes the
    /// adopter restarts them from. `epoch` is the roster epoch this
    /// reassignment creates (0 = the initial assignment; each adoption
    /// bumps it), so owner-map updates are ordered and `/status` can
    /// report which roster generation every worker's shard belongs to
    Adopt { to_rank: u32, from_rank: u32, epoch: u32, entries: Vec<NodeLanes> },
    /// worker → coordinator on shutdown: final payload lanes + final
    /// counters + the staleness histogram raw parts
    Done {
        entries: Vec<NodeLanes>,
        progress: ProgressBody,
        stale_buckets: Vec<u64>,
        stale_overflow: u64,
        stale_count: u64,
        stale_sum: u128,
        stale_max: u64,
    },
    /// coordinator → worker: stop gossiping, send `Done`
    Shutdown { reason: String },
    /// worker ↔ worker: one node's published payload (broadcast on ring)
    Publish { node: u32, enc: PayloadEnc },
    /// worker → owning worker: best-effort cross-write payload for a
    /// remote partner (applied via `try_publish`, dropped + counted on
    /// conflict — nobody ever waits)
    Cross { node: u32, lanes: Vec<f32> },
    /// worker ↔ worker, first frame on a gossip connection
    PeerHello { rank: u32 },
    /// coordinator → worker round-trip-time probe; `t_ns` is the
    /// coordinator's monotonic send time, echoed back verbatim in `Pong`
    /// (the clock never crosses machines, so no synchronization is needed)
    Ping { t_ns: u64 },
    /// worker → coordinator: `Ping.t_ns` echoed; RTT = now − t_ns at the
    /// coordinator
    Pong { t_ns: u64 },
}

const K_HELLO: u8 = 1;
const K_ASSIGN: u8 = 2;
const K_PROGRESS: u8 = 3;
const K_CHECKPOINT: u8 = 4;
const K_ADOPT: u8 = 5;
const K_DONE: u8 = 6;
const K_SHUTDOWN: u8 = 7;
const K_PUBLISH: u8 = 8;
const K_CROSS: u8 = 9;
const K_PEER_HELLO: u8 = 10;
const K_PING: u8 = 11;
const K_PONG: u8 = 12;

impl Msg {
    /// Serialize to one complete frame (header + payload + checksum).
    pub fn to_frame(&self) -> Vec<u8> {
        let mut w = Wr::new();
        let kind = match self {
            Msg::Hello { gossip_port } => {
                w.u16(*gossip_port);
                K_HELLO
            }
            Msg::Assign { rank, workers, config_ini, owned, peers } => {
                w.u32(*rank);
                w.u32(*workers);
                w.str(config_ini);
                w.u32(owned.len() as u32);
                for &k in owned {
                    w.u32(k);
                }
                w.u32(peers.len() as u32);
                for p in peers {
                    w.u32(p.rank);
                    w.str(&p.addr);
                }
                K_ASSIGN
            }
            Msg::Progress(p) => {
                p.write(&mut w);
                K_PROGRESS
            }
            Msg::Checkpoint { events, entries } => {
                w.u64(*events);
                write_node_lanes(&mut w, entries);
                K_CHECKPOINT
            }
            Msg::Adopt { to_rank, from_rank, epoch, entries } => {
                w.u32(*to_rank);
                w.u32(*from_rank);
                w.u32(*epoch);
                write_node_lanes(&mut w, entries);
                K_ADOPT
            }
            Msg::Done {
                entries,
                progress,
                stale_buckets,
                stale_overflow,
                stale_count,
                stale_sum,
                stale_max,
            } => {
                write_node_lanes(&mut w, entries);
                progress.write(&mut w);
                w.u64s(stale_buckets);
                w.u64(*stale_overflow);
                w.u64(*stale_count);
                w.u64((*stale_sum >> 64) as u64);
                w.u64(*stale_sum as u64);
                w.u64(*stale_max);
                K_DONE
            }
            Msg::Shutdown { reason } => {
                w.str(reason);
                K_SHUTDOWN
            }
            Msg::Publish { node, enc } => {
                w.u32(*node);
                match enc {
                    PayloadEnc::F32 { lanes } => {
                        w.u8(0);
                        w.f32s(lanes);
                    }
                    PayloadEnc::Lattice { bits, eps, seed, len, checksum, packed, aux } => {
                        w.u8(1);
                        w.u32(*bits);
                        w.f32(*eps);
                        w.u32(*seed);
                        w.u32(*len);
                        w.u64(*checksum);
                        w.bytes(packed);
                        w.f32s(aux);
                    }
                }
                K_PUBLISH
            }
            Msg::Cross { node, lanes } => {
                w.u32(*node);
                w.f32s(lanes);
                K_CROSS
            }
            Msg::PeerHello { rank } => {
                w.u32(*rank);
                K_PEER_HELLO
            }
            Msg::Ping { t_ns } => {
                w.u64(*t_ns);
                K_PING
            }
            Msg::Pong { t_ns } => {
                w.u64(*t_ns);
                K_PONG
            }
        };
        encode_frame(kind, &w.0)
    }

    /// Decode a complete frame back into a message.
    pub fn from_frame(frame: &Frame) -> Result<Msg, String> {
        let mut r = Rd::new(&frame.payload);
        let msg = match frame.kind {
            K_HELLO => Msg::Hello { gossip_port: r.u16()? },
            K_ASSIGN => {
                let rank = r.u32()?;
                let workers = r.u32()?;
                let config_ini = r.str()?;
                let owned = (0..r.len_prefix()?).map(|_| r.u32()).collect::<Result<_, _>>()?;
                let peers = (0..r.len_prefix()?)
                    .map(|_| Ok(PeerAddr { rank: r.u32()?, addr: r.str()? }))
                    .collect::<Result<_, String>>()?;
                Msg::Assign { rank, workers, config_ini, owned, peers }
            }
            K_PROGRESS => Msg::Progress(ProgressBody::read(&mut r)?),
            K_CHECKPOINT => {
                Msg::Checkpoint { events: r.u64()?, entries: read_node_lanes(&mut r)? }
            }
            K_ADOPT => Msg::Adopt {
                to_rank: r.u32()?,
                from_rank: r.u32()?,
                epoch: r.u32()?,
                entries: read_node_lanes(&mut r)?,
            },
            K_DONE => {
                let entries = read_node_lanes(&mut r)?;
                let progress = ProgressBody::read(&mut r)?;
                let stale_buckets = r.u64s()?;
                let stale_overflow = r.u64()?;
                let stale_count = r.u64()?;
                let hi = r.u64()?;
                let lo = r.u64()?;
                let stale_max = r.u64()?;
                Msg::Done {
                    entries,
                    progress,
                    stale_buckets,
                    stale_overflow,
                    stale_count,
                    stale_sum: ((hi as u128) << 64) | lo as u128,
                    stale_max,
                }
            }
            K_SHUTDOWN => Msg::Shutdown { reason: r.str()? },
            K_PUBLISH => {
                let node = r.u32()?;
                let enc = match r.u8()? {
                    0 => PayloadEnc::F32 { lanes: r.f32s()? },
                    1 => PayloadEnc::Lattice {
                        bits: r.u32()?,
                        eps: r.f32()?,
                        seed: r.u32()?,
                        len: r.u32()?,
                        checksum: r.u64()?,
                        packed: r.bytes()?,
                        aux: r.f32s()?,
                    },
                    t => return Err(format!("unknown payload encoding tag {t}")),
                };
                Msg::Publish { node, enc }
            }
            K_CROSS => Msg::Cross { node: r.u32()?, lanes: r.f32s()? },
            K_PEER_HELLO => Msg::PeerHello { rank: r.u32()? },
            K_PING => Msg::Ping { t_ns: r.u64()? },
            K_PONG => Msg::Pong { t_ns: r.u64()? },
            k => return Err(format!("unknown message kind {k}")),
        };
        r.done()?;
        Ok(msg)
    }

    /// Build a `Done` message from final states + a histogram.
    pub fn done(
        entries: Vec<NodeLanes>,
        progress: ProgressBody,
        staleness: &StalenessHistogram,
    ) -> Msg {
        let (buckets, overflow, count, sum, max) = staleness.raw_parts();
        Msg::Done {
            entries,
            progress,
            stale_buckets: buckets.to_vec(),
            stale_overflow: overflow,
            stale_count: count,
            stale_sum: sum,
            stale_max: max,
        }
    }
}

/// Reassemble the staleness histogram a `Done` message carries.
pub fn done_staleness(msg: &Msg) -> Option<StalenessHistogram> {
    match msg {
        Msg::Done { stale_buckets, stale_overflow, stale_count, stale_sum, stale_max, .. } => {
            Some(StalenessHistogram::from_raw(
                stale_buckets.clone(),
                *stale_overflow,
                *stale_count,
                *stale_sum,
                *stale_max,
            ))
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Pcg64;

    fn sample_msgs() -> Vec<Msg> {
        vec![
            Msg::Hello { gossip_port: 40123 },
            Msg::Assign {
                rank: 1,
                workers: 3,
                config_ini: "[run]\nn = 16\nalgo = swarm\n".into(),
                owned: vec![1, 4, 7],
                peers: vec![
                    PeerAddr { rank: 0, addr: "127.0.0.1:9000".into() },
                    PeerAddr { rank: 1, addr: "127.0.0.1:9001".into() },
                ],
            },
            Msg::Progress(ProgressBody {
                events: 123,
                steps: 246,
                wire_bits: 9_999,
                wire_fallbacks: 1,
                read_retries: 2,
                publish_retries: 3,
                push_conflicts: 4,
                busy_us: 5_000,
                wait_us: 70,
            }),
            Msg::Checkpoint {
                events: 55,
                entries: vec![
                    NodeLanes { node: 0, lanes: vec![1.0, -2.5, f32::NAN] },
                    NodeLanes { node: 9, lanes: vec![] },
                ],
            },
            Msg::Adopt {
                to_rank: 0,
                from_rank: 2,
                epoch: 3,
                entries: vec![NodeLanes { node: 5, lanes: vec![0.25; 8] }],
            },
            Msg::Done {
                entries: vec![NodeLanes { node: 3, lanes: vec![9.0; 4] }],
                progress: ProgressBody { events: 7, ..Default::default() },
                stale_buckets: vec![4, 0, 2],
                stale_overflow: 1,
                stale_count: 7,
                stale_sum: (3u128 << 64) | 17,
                stale_max: 900,
            },
            Msg::Shutdown { reason: "job complete".into() },
            Msg::Publish {
                node: 12,
                enc: PayloadEnc::Lattice {
                    bits: 8,
                    eps: 1e-3,
                    seed: 77,
                    len: 5,
                    checksum: 0xdead_beef,
                    packed: vec![1, 2, 3, 4, 5],
                    aux: vec![0.5],
                },
            },
            Msg::Publish { node: 0, enc: PayloadEnc::F32 { lanes: vec![1.0, 2.0] } },
            Msg::Cross { node: 2, lanes: vec![-1.0, 1.0] },
            Msg::PeerHello { rank: 2 },
            Msg::Ping { t_ns: 123_456_789 },
            Msg::Pong { t_ns: u64::MAX },
        ]
    }

    fn roundtrip(m: &Msg) -> Msg {
        let bytes = m.to_frame();
        let mut dec = FrameDecoder::new();
        dec.feed(&bytes);
        let frame = dec.next_frame().unwrap().expect("complete frame");
        assert_eq!(dec.pending(), 0);
        Msg::from_frame(&frame).unwrap()
    }

    fn msgs_eq(a: &Msg, b: &Msg) {
        // NaN lanes make derived PartialEq false; compare via Debug (which
        // prints NaN stably) so checkpoint frames with NaN lanes round-trip
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn every_message_roundtrips() {
        for m in sample_msgs() {
            msgs_eq(&roundtrip(&m), &m);
        }
    }

    #[test]
    fn frame_layout_has_the_documented_length_prefix() {
        let payload = vec![7u8; 33];
        let bytes = encode_frame(K_CROSS, &payload);
        assert_eq!(bytes.len(), HEADER_LEN + 33 + CHECKSUM_LEN);
        assert_eq!(&bytes[..4], &MAGIC);
        assert_eq!(u16::from_le_bytes([bytes[4], bytes[5]]), PROTO_VERSION);
        assert_eq!(bytes[6], K_CROSS);
        assert_eq!(u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]), 33);
        assert_eq!(&bytes[HEADER_LEN..HEADER_LEN + 33], &payload[..]);
    }

    #[test]
    fn partial_reads_resume_at_any_split_point() {
        // feed a multi-message byte stream one irregular chunk at a time;
        // the decoder must yield exactly the original messages, in order,
        // regardless of where the chunk boundaries fall
        let msgs = sample_msgs();
        let mut stream = Vec::new();
        for m in &msgs {
            stream.extend_from_slice(&m.to_frame());
        }
        let mut rng = Pcg64::seed(42);
        for _ in 0..20 {
            let mut dec = FrameDecoder::new();
            let mut got = Vec::new();
            let mut i = 0;
            while i < stream.len() {
                let chunk = (rng.below_usize(23) + 1).min(stream.len() - i);
                dec.feed(&stream[i..i + chunk]);
                i += chunk;
                while let Some(f) = dec.next_frame().unwrap() {
                    got.push(Msg::from_frame(&f).unwrap());
                }
            }
            assert_eq!(got.len(), msgs.len());
            for (g, m) in got.iter().zip(&msgs) {
                msgs_eq(g, m);
            }
            assert_eq!(dec.pending(), 0);
        }
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let mut bytes = Msg::Hello { gossip_port: 1 }.to_frame();
        bytes[4] = 99; // version lane
        // checksum covers the header, so recompute it to isolate the
        // version check from the checksum check
        let body_len = bytes.len() - CHECKSUM_LEN;
        let sum = fnv1a(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&sum.to_le_bytes());
        let mut dec = FrameDecoder::new();
        dec.feed(&bytes);
        assert_eq!(dec.next_frame(), Err(FrameError::VersionMismatch { got: 99 }));
    }

    #[test]
    fn corruption_anywhere_is_detected() {
        let bytes = Msg::Cross { node: 3, lanes: vec![1.0, 2.0, 3.0] }.to_frame();
        // flip one bit at every single position: every corruption must be
        // rejected (magic, version, checksum...), never decoded as valid
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x04;
            let mut dec = FrameDecoder::new();
            dec.feed(&bad);
            match dec.next_frame() {
                Err(_) => {}
                Ok(None) => {} // corrupt length prefix now promises more bytes
                Ok(Some(f)) => panic!("bit-flip at byte {i} decoded as valid frame {f:?}"),
            }
        }
    }

    #[test]
    fn oversized_length_prefix_is_rejected_without_allocating() {
        let mut bytes = encode_frame(K_HELLO, &[0, 0]);
        bytes[8..12].copy_from_slice(&(u32::MAX).to_le_bytes());
        let mut dec = FrameDecoder::new();
        dec.feed(&bytes);
        assert!(matches!(dec.next_frame(), Err(FrameError::TooLarge { .. })));
    }

    #[test]
    fn bad_magic_is_rejected_immediately() {
        let mut dec = FrameDecoder::new();
        dec.feed(b"HTTP/1.1 200 OK\r\n");
        assert_eq!(dec.next_frame(), Err(FrameError::BadMagic));
    }

    #[test]
    fn decoder_compacts_consumed_prefix() {
        // many frames through one decoder: the internal buffer must not
        // grow with the total byte count (compaction on feed)
        let frame = Msg::PeerHello { rank: 7 }.to_frame();
        let mut dec = FrameDecoder::new();
        for _ in 0..10_000 {
            dec.feed(&frame);
            assert!(dec.next_frame().unwrap().is_some());
        }
        assert_eq!(dec.pending(), 0);
        assert!(dec.buf.len() < 4 * frame.len(), "buffer grew: {}", dec.buf.len());
    }

    #[test]
    fn done_staleness_reassembles_the_histogram() {
        let mut h = StalenessHistogram::new(8);
        for v in [0u64, 2, 2, 50] {
            h.record(v);
        }
        let m = Msg::done(vec![], ProgressBody::default(), &h);
        let m = roundtrip(&m);
        let back = done_staleness(&m).unwrap();
        assert_eq!(back.count(), h.count());
        assert_eq!(back.p50(), h.p50());
        assert_eq!(back.max_observed(), h.max_observed());
        assert!((back.mean() - h.mean()).abs() < 1e-12);
        assert_eq!(done_staleness(&Msg::Hello { gossip_port: 0 }), None);
    }

    #[test]
    fn truncated_message_payload_is_an_error_not_a_panic() {
        let frame = Msg::Assign {
            rank: 0,
            workers: 2,
            config_ini: "[run]\n".into(),
            owned: vec![0, 2],
            peers: vec![],
        }
        .to_frame();
        // re-frame a truncated payload (valid frame, short message body)
        let payload = &frame[HEADER_LEN..frame.len() - CHECKSUM_LEN];
        for cut in 0..payload.len() {
            let bytes = encode_frame(K_ASSIGN, &payload[..cut]);
            let mut dec = FrameDecoder::new();
            dec.feed(&bytes);
            let f = dec.next_frame().unwrap().unwrap();
            assert!(Msg::from_frame(&f).is_err(), "cut at {cut} decoded");
        }
    }
}
