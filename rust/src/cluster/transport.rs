//! Blocking TCP transport for [`proto`](super::proto) frames.
//!
//! Deliberately thin: one function to write a message (returning the real
//! byte count so `wire_bits` measures actual socket traffic, not a model),
//! and a [`FrameConn`] that pairs a stream with an incremental
//! [`FrameDecoder`](super::proto::FrameDecoder) for blocking reads. All
//! concurrency lives in the worker/coordinator threads that own these
//! connections — the transport itself has no threads, no queues, and no
//! retry policy beyond the initial connect.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use super::proto::{FrameDecoder, Msg};

/// Serialize `msg` and write it to `stream`. Returns the number of bytes
/// that hit the socket — the cluster's ground truth for `wire_bits`.
pub fn send_msg(stream: &mut TcpStream, msg: &Msg) -> std::io::Result<usize> {
    let bytes = msg.to_frame();
    stream.write_all(&bytes)?;
    Ok(bytes.len())
}

/// A TCP stream plus the decoder state for reading framed messages off it.
pub struct FrameConn {
    pub stream: TcpStream,
    decoder: FrameDecoder,
}

impl FrameConn {
    pub fn new(stream: TcpStream) -> Self {
        FrameConn { stream, decoder: FrameDecoder::new() }
    }

    /// Block until one complete message arrives. `Ok(None)` means the peer
    /// closed the connection cleanly (EOF between frames); errors cover
    /// socket failures, protocol violations, and EOF mid-frame.
    pub fn read_msg(&mut self) -> std::io::Result<Option<Msg>> {
        let mut buf = [0u8; 16 * 1024];
        loop {
            if let Some(frame) = self
                .decoder
                .next_frame()
                .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))?
            {
                let msg = Msg::from_frame(&frame)
                    .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
                return Ok(Some(msg));
            }
            let n = self.stream.read(&mut buf)?;
            if n == 0 {
                if self.decoder.pending() > 0 {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "peer closed the connection mid-frame",
                    ));
                }
                return Ok(None);
            }
            self.decoder.feed(&buf[..n]);
        }
    }
}

/// Connect to `addr`, retrying for up to `deadline` — covers the startup
/// race where workers dial the coordinator (or each other's gossip
/// listeners) before the listener has finished binding.
pub fn connect_with_retry(addr: &str, deadline: Duration) -> std::io::Result<TcpStream> {
    let start = Instant::now();
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => {
                s.set_nodelay(true).ok();
                return Ok(s);
            }
            Err(e) if start.elapsed() < deadline => {
                let _ = e;
                std::thread::sleep(Duration::from_millis(50));
            }
            Err(e) => {
                return Err(std::io::Error::new(
                    e.kind(),
                    format!("could not connect to {addr} within {deadline:?}: {e}"),
                ))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn send_and_read_roundtrip_over_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let t = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut conn = FrameConn::new(stream);
            let mut got = Vec::new();
            while let Some(msg) = conn.read_msg().unwrap() {
                got.push(msg);
            }
            got
        });
        let mut stream = connect_with_retry(&addr, Duration::from_secs(2)).unwrap();
        let msgs = [
            Msg::Hello { gossip_port: 7 },
            Msg::Cross { node: 1, lanes: vec![1.0, -2.0] },
            Msg::Shutdown { reason: "done".into() },
        ];
        let mut bytes = 0;
        for m in &msgs {
            bytes += send_msg(&mut stream, m).unwrap();
        }
        drop(stream); // clean EOF
        let got = t.join().unwrap();
        assert_eq!(got.len(), msgs.len());
        assert_eq!(got[1], msgs[1]);
        // real byte count: every frame carries header + checksum overhead
        assert!(bytes > msgs.iter().map(|m| m.to_frame().len() - 20).sum::<usize>());
    }

    #[test]
    fn connect_with_retry_reports_the_address_on_failure() {
        // a port nobody listens on (bind + drop reserves then releases it)
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let err = connect_with_retry(&addr, Duration::from_millis(100)).unwrap_err();
        assert!(err.to_string().contains(&addr), "error should name the address: {err}");
    }
}
