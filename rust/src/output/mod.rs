//! Result output: CSV series, aligned console tables, and `.npy` model
//! checkpoints (DESIGN.md S20).

mod checkpoint;

pub use checkpoint::{load_npy, save_npy};

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Write a CSV with a header row; values are formatted with enough digits
/// for downstream plotting.
pub struct CsvWriter {
    w: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", header.join(","))?;
        Ok(Self { w, cols: header.len() })
    }

    pub fn row(&mut self, values: &[f64]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.cols, "CSV row width mismatch");
        let line: Vec<String> = values.iter().map(|v| format!("{v:.6e}")).collect();
        writeln!(self.w, "{}", line.join(","))
    }

    pub fn row_mixed(&mut self, values: &[CsvVal]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.cols, "CSV row width mismatch");
        let line: Vec<String> = values.iter().map(|v| v.to_string()).collect();
        writeln!(self.w, "{}", line.join(","))
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.w.flush()
    }
}

/// Mixed-type CSV cell.
pub enum CsvVal {
    F(f64),
    I(i64),
    S(String),
}

impl std::fmt::Display for CsvVal {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CsvVal::F(v) => write!(f, "{v:.6e}"),
            CsvVal::I(v) => write!(f, "{v}"),
            CsvVal::S(s) => write!(f, "{s}"),
        }
    }
}

/// Console table with aligned columns (paper-style rows).
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "table row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rows_str(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("swarm_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&[1.0, 2.5]).unwrap();
            w.row_mixed(&[CsvVal::I(3), CsvVal::S("x".into())]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "a,b");
        assert!(lines[1].starts_with("1.0"));
        assert_eq!(lines[2], "3,x");
    }

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["method", "acc"]);
        t.rows_str(&["swarm", "0.91"]);
        t.rows_str(&["ad-psgd-longer", "0.90"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        // columns aligned: "acc" starts at same offset everywhere
        let off = lines[0].find("acc").unwrap();
        assert_eq!(&lines[2][off..off + 4], "0.91");
    }

    #[test]
    #[should_panic]
    fn csv_width_checked() {
        let dir = std::env::temp_dir().join("swarm_csv_test2");
        let mut w = CsvWriter::create(dir.join("t.csv"), &["a"]).unwrap();
        w.row(&[1.0, 2.0]).unwrap();
    }
}
