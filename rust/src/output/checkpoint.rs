//! Model checkpointing: flat parameter vectors as `.npy` files (v1.0,
//! little-endian f32, 1-D) — loadable by numpy/JAX for offline analysis,
//! and reloadable by the coordinator to resume or evaluate.
//!
//! # Integrity trailer
//!
//! `save_npy` appends a 24-byte versioned trailer **after** the npy
//! payload: magic `SWCK`, a format version, the element count, and an
//! FNV-1a checksum of the payload bytes. numpy readers stop at the shape
//! declared in the header, so the trailer is invisible to them; `load_npy`
//! verifies it so a truncated or bit-rotted checkpoint is rejected with an
//! actionable error instead of silently feeding garbage lanes into a
//! restart (the cluster executor reassigns dead-worker shards from these
//! files). Files written by plain numpy (no trailer) still load — only the
//! header-declared length is then enforced.

use std::io::{Read, Write};
use std::path::Path;

/// Trailer magic — "SWCK" (SwarmSGD checkpoint).
const TRAILER_MAGIC: &[u8; 4] = b"SWCK";
/// Trailer format version; bump on layout changes.
const TRAILER_VERSION: u16 = 1;

/// FNV-1a over the raw little-endian payload bytes.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn bad(msg: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

/// Write a flat f32 vector as a 1-D `.npy` (format 1.0) with the SWCK
/// integrity trailer.
pub fn save_npy(path: &Path, data: &[f32]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    let header_body = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': ({},), }}",
        data.len()
    );
    // pad header (incl. trailing \n) so that 10 + len is a multiple of 64
    let unpadded = 10 + header_body.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    let header = format!("{header_body}{}\n", " ".repeat(pad));
    f.write_all(b"\x93NUMPY\x01\x00")?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    let mut buf = Vec::with_capacity(data.len() * 4);
    for v in data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    f.write_all(&buf)?;
    // trailer: magic + version + reserved + element count + payload checksum
    f.write_all(TRAILER_MAGIC)?;
    f.write_all(&TRAILER_VERSION.to_le_bytes())?;
    f.write_all(&0u16.to_le_bytes())?;
    f.write_all(&(data.len() as u64).to_le_bytes())?;
    f.write_all(&fnv1a(&buf).to_le_bytes())
}

/// Parse the element count out of the npy header dict's `'shape': (N,)`.
fn header_count(header: &str) -> std::io::Result<usize> {
    let after = header
        .split("'shape':")
        .nth(1)
        .and_then(|s| s.split('(').nth(1))
        .and_then(|s| s.split([',', ')']).next())
        .map(str::trim)
        .ok_or_else(|| bad(format!("npy header has no parsable shape: {header}")))?;
    if after.is_empty() {
        // numpy writes a 0-d scalar as '()'; we only ever write 1-D
        return Err(bad(format!("expected 1-D shape, header: {header}")));
    }
    after
        .parse()
        .map_err(|_| bad(format!("bad element count '{after}' in npy header")))
}

/// Read a 1-D little-endian f32 `.npy` written by [`save_npy`] (or numpy).
///
/// The header-declared element count is always enforced (a truncated file
/// is an error, not a short vector); when the SWCK trailer is present its
/// version, count, and checksum are verified too.
pub fn load_npy(path: &Path) -> std::io::Result<Vec<f32>> {
    let mut f = std::fs::File::open(path)?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic[..6] != b"\x93NUMPY" {
        return Err(bad("not an npy file".into()));
    }
    let mut hlen = [0u8; 2];
    f.read_exact(&mut hlen)?;
    let hlen = u16::from_le_bytes(hlen) as usize;
    let mut header = vec![0u8; hlen];
    f.read_exact(&mut header)?;
    let header = String::from_utf8_lossy(&header);
    if !header.contains("'<f4'") {
        return Err(bad(format!("expected <f4 dtype, header: {header}")));
    }
    let count = header_count(&header)?;
    let mut raw = vec![0u8; count * 4];
    f.read_exact(&mut raw).map_err(|e| {
        bad(format!(
            "checkpoint truncated: header declares {count} f32 elements \
             ({} payload bytes) but the file ends early ({e}); \
             re-save or restore from an earlier checkpoint",
            count * 4
        ))
    })?;
    let mut trailer = [0u8; 24];
    match f.read_exact(&mut trailer) {
        Ok(()) => {
            if &trailer[..4] != TRAILER_MAGIC {
                return Err(bad(
                    "unexpected bytes after the npy payload (not an SWCK trailer); \
                     file may be corrupt or not 1-D"
                        .into(),
                ));
            }
            let version = u16::from_le_bytes([trailer[4], trailer[5]]);
            if version != TRAILER_VERSION {
                return Err(bad(format!(
                    "unsupported checkpoint trailer version {version} \
                     (this build reads version {TRAILER_VERSION})"
                )));
            }
            let tcount = u64::from_le_bytes(trailer[8..16].try_into().unwrap());
            if tcount != count as u64 {
                return Err(bad(format!(
                    "checkpoint corrupt: trailer element count {tcount} \
                     disagrees with the npy header ({count})"
                )));
            }
            let want = u64::from_le_bytes(trailer[16..24].try_into().unwrap());
            let got = fnv1a(&raw);
            if got != want {
                return Err(bad(format!(
                    "checkpoint corrupt: payload checksum {got:#018x} does not \
                     match the trailer's {want:#018x}; restore from an earlier \
                     checkpoint"
                )));
            }
        }
        // plain numpy file: no trailer at all is fine (length was enforced
        // above); a *partial* trailer means the file was cut mid-write.
        // read_exact's buffer contents are unspecified on EOF, so the two
        // cases are told apart by total file length.
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            let total = std::fs::metadata(path)?.len();
            let payload_end = (10 + hlen + count * 4) as u64;
            if total != payload_end {
                return Err(bad(format!(
                    "checkpoint truncated: {} trailing bytes after the payload \
                     (a complete SWCK trailer is 24); the file was cut mid-write",
                    total.saturating_sub(payload_end)
                )));
            }
        }
        Err(e) => return Err(e),
    }
    Ok(raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir()
            .join(format!("swarm_npy_{}_{}", name, std::process::id()))
            .join("model.npy")
    }

    #[test]
    fn roundtrip() {
        let path = tmp("rt");
        let data: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5 - 3.0).collect();
        save_npy(&path, &data).unwrap();
        let back = load_npy(&path).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn header_is_64_aligned() {
        let path = tmp("align");
        save_npy(&path, &[1.0, 2.0]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let hlen = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
        assert_eq!((10 + hlen) % 64, 0);
        // payload precedes the 24-byte trailer
        let payload = &bytes[10 + hlen..bytes.len() - 24];
        assert_eq!(payload, &[0, 0, 128, 63, 0, 0, 0, 64]);
        assert_eq!(&bytes[bytes.len() - 24..bytes.len() - 20], b"SWCK");
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("garbage");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, b"not an npy at all").unwrap();
        assert!(load_npy(&path).is_err());
    }

    #[test]
    fn empty_vector_roundtrips() {
        let path = tmp("empty");
        save_npy(&path, &[]).unwrap();
        assert!(load_npy(&path).unwrap().is_empty());
    }

    #[test]
    fn nan_and_inf_lanes_roundtrip_bit_exactly() {
        let path = tmp("nonfinite");
        let data = vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0, 1.5e-42];
        save_npy(&path, &data).unwrap();
        let back = load_npy(&path).unwrap();
        assert_eq!(back.len(), data.len());
        for (b, d) in back.iter().zip(&data) {
            assert_eq!(b.to_bits(), d.to_bits(), "lanes must round-trip bit-exactly");
        }
    }

    #[test]
    fn truncated_payload_is_rejected_with_an_actionable_error() {
        let path = tmp("trunc");
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        save_npy(&path, &data).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // cut the file inside the payload: the header still promises 64
        std::fs::write(&path, &bytes[..bytes.len() - 24 - 40]).unwrap();
        let err = load_npy(&path).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("truncated"), "unhelpful error: {msg}");
        assert!(msg.contains("64"), "should name the declared count: {msg}");
    }

    #[test]
    fn torn_trailer_is_rejected() {
        let path = tmp("torn");
        save_npy(&path, &[1.0, 2.0, 3.0]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // keep the payload intact but cut the trailer in half
        std::fs::write(&path, &bytes[..bytes.len() - 12]).unwrap();
        let err = load_npy(&path).unwrap_err();
        assert!(err.to_string().contains("trailer"), "unhelpful error: {err}");
    }

    #[test]
    fn corrupted_payload_fails_the_checksum() {
        let path = tmp("corrupt");
        let data: Vec<f32> = (0..32).map(|i| i as f32 * 0.25).collect();
        save_npy(&path, &data).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // flip one payload bit (well inside the data region)
        let mid = bytes.len() - 24 - 17;
        bytes[mid] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let err = load_npy(&path).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("checksum"), "unhelpful error: {msg}");
    }

    #[test]
    fn plain_numpy_file_without_trailer_still_loads() {
        // a foreign file written by numpy itself has no SWCK trailer; the
        // header-declared length is still enforced
        let path = tmp("foreign");
        save_npy(&path, &[4.0, 5.0]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 24]).unwrap();
        assert_eq!(load_npy(&path).unwrap(), vec![4.0, 5.0]);
    }
}
