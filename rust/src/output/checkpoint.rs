//! Model checkpointing: flat parameter vectors as `.npy` files (v1.0,
//! little-endian f32, 1-D) — loadable by numpy/JAX for offline analysis,
//! and reloadable by the coordinator to resume or evaluate.

use std::io::{Read, Write};
use std::path::Path;

/// Write a flat f32 vector as a 1-D `.npy` (format 1.0).
pub fn save_npy(path: &Path, data: &[f32]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    let header_body = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': ({},), }}",
        data.len()
    );
    // pad header (incl. trailing \n) so that 10 + len is a multiple of 64
    let unpadded = 10 + header_body.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    let header = format!("{header_body}{}\n", " ".repeat(pad));
    f.write_all(b"\x93NUMPY\x01\x00")?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    let mut buf = Vec::with_capacity(data.len() * 4);
    for v in data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    f.write_all(&buf)
}

/// Read a 1-D little-endian f32 `.npy` written by [`save_npy`] (or numpy).
pub fn load_npy(path: &Path) -> std::io::Result<Vec<f32>> {
    let mut f = std::fs::File::open(path)?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic[..6] != b"\x93NUMPY" {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "not an npy file",
        ));
    }
    let mut hlen = [0u8; 2];
    f.read_exact(&mut hlen)?;
    let hlen = u16::from_le_bytes(hlen) as usize;
    let mut header = vec![0u8; hlen];
    f.read_exact(&mut header)?;
    let header = String::from_utf8_lossy(&header);
    if !header.contains("'<f4'") {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("expected <f4 dtype, header: {header}"),
        ));
    }
    let mut raw = Vec::new();
    f.read_to_end(&mut raw)?;
    if raw.len() % 4 != 0 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "payload not a multiple of 4 bytes",
        ));
    }
    Ok(raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("swarm_npy_{}", std::process::id()));
        let path = dir.join("model.npy");
        let data: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5 - 3.0).collect();
        save_npy(&path, &data).unwrap();
        let back = load_npy(&path).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn header_is_64_aligned() {
        let dir = std::env::temp_dir().join(format!("swarm_npy2_{}", std::process::id()));
        let path = dir.join("m.npy");
        save_npy(&path, &[1.0, 2.0]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let hlen = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
        assert_eq!((10 + hlen) % 64, 0);
        // payload
        assert_eq!(&bytes[10 + hlen..], &[0, 0, 128, 63, 0, 0, 0, 64]);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("swarm_npy3_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.npy");
        std::fs::write(&path, b"not an npy at all").unwrap();
        assert!(load_npy(&path).is_err());
    }

    #[test]
    fn empty_vector() {
        let dir = std::env::temp_dir().join(format!("swarm_npy4_{}", std::process::id()));
        let path = dir.join("empty.npy");
        save_npy(&path, &[]).unwrap();
        assert!(load_npy(&path).unwrap().is_empty());
    }
}
