//! Γ_t = Σ_i ‖X_t^i − μ_t‖² — the paper's load-balancing potential (Eq. 6).
//!
//! The whole analysis rests on Γ_t staying bounded *independently of t*
//! (Lemma F.3: E[Γ_t] ≤ (40r/λ₂ + 80r²/λ₂²)·n·η²H²M²). The tracker computes
//! it exactly over all agents; the `gamma` figure harness plots it against
//! the lemma's bound.

/// Coordinate-wise mean of the agents' models.
pub fn mean_model(models: &[Vec<f32>]) -> Vec<f64> {
    let n = models.len();
    assert!(n > 0);
    let d = models[0].len();
    let mut mu = vec![0.0f64; d];
    for m in models {
        debug_assert_eq!(m.len(), d);
        for (a, &v) in mu.iter_mut().zip(m.iter()) {
            *a += v as f64;
        }
    }
    for a in &mut mu {
        *a /= n as f64;
    }
    mu
}

/// Γ = Σ_i ‖X^i − μ‖².
pub fn gamma_potential(models: &[Vec<f32>]) -> f64 {
    let mu = mean_model(models);
    models
        .iter()
        .map(|m| {
            m.iter()
                .zip(&mu)
                .map(|(&x, &u)| (x as f64 - u).powi(2))
                .sum::<f64>()
        })
        .sum()
}

/// Incremental tracker: records (t, Γ_t, ‖μ_t‖) samples during a run.
#[derive(Default)]
pub struct GammaTracker {
    pub samples: Vec<(u64, f64)>,
    pub mu_norms: Vec<(u64, f64)>,
}

impl GammaTracker {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, t: u64, models: &[Vec<f32>]) {
        let g = gamma_potential(models);
        let mu = mean_model(models);
        let norm = mu.iter().map(|v| v * v).sum::<f64>().sqrt();
        self.samples.push((t, g));
        self.mu_norms.push((t, norm));
    }

    pub fn max_gamma(&self) -> f64 {
        self.samples.iter().map(|&(_, g)| g).fold(0.0, f64::max)
    }

    /// Mean Γ over the second half of the run (steady state).
    pub fn steady_state_gamma(&self) -> f64 {
        let half = self.samples.len() / 2;
        let tail = &self.samples[half..];
        if tail.is_empty() {
            return 0.0;
        }
        tail.iter().map(|&(_, g)| g).sum::<f64>() / tail.len() as f64
    }
}

/// Lemma F.3 upper bound: (40r/λ₂ + 80r²/λ₂²)·n·η²·H²·M².
pub fn lemma_f3_bound(r: f64, lambda2: f64, n: usize, eta: f64, h: f64, m_sq: f64) -> f64 {
    (40.0 * r / lambda2 + 80.0 * r * r / (lambda2 * lambda2))
        * n as f64
        * eta
        * eta
        * h
        * h
        * m_sq
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_models_have_zero_gamma() {
        let models = vec![vec![1.0f32, 2.0, 3.0]; 5];
        assert_eq!(gamma_potential(&models), 0.0);
    }

    #[test]
    fn gamma_known_value() {
        // two models at ±1 in 1-D: μ=0, Γ = 1 + 1 = 2
        let models = vec![vec![1.0f32], vec![-1.0f32]];
        assert!((gamma_potential(&models) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn gamma_invariant_to_common_shift() {
        let a = vec![vec![0.5f32, -1.0], vec![2.0, 0.25], vec![-0.75, 3.0]];
        let b: Vec<Vec<f32>> = a
            .iter()
            .map(|m| m.iter().map(|v| v + 10.0).collect())
            .collect();
        assert!((gamma_potential(&a) - gamma_potential(&b)).abs() < 1e-4);
    }

    #[test]
    fn averaging_two_models_decreases_gamma() {
        // the load-balancing contraction that drives Lemma F.1
        let mut models = vec![
            vec![4.0f32, 0.0],
            vec![0.0, 4.0],
            vec![-4.0, 0.0],
            vec![0.0, -4.0],
        ];
        let before = gamma_potential(&models);
        let avg: Vec<f32> = models[0]
            .iter()
            .zip(&models[1])
            .map(|(a, b)| (a + b) / 2.0)
            .collect();
        models[0] = avg.clone();
        models[1] = avg;
        assert!(gamma_potential(&models) < before);
    }

    #[test]
    fn tracker_steady_state() {
        let mut t = GammaTracker::new();
        let m1 = vec![vec![0.0f32], vec![2.0f32]];
        for i in 0..10 {
            t.record(i, &m1);
        }
        assert!((t.steady_state_gamma() - 2.0).abs() < 1e-9);
        assert_eq!(t.max_gamma(), 2.0);
    }

    #[test]
    fn f3_bound_monotone_in_h() {
        let b1 = lemma_f3_bound(4.0, 2.0, 16, 0.01, 1.0, 1.0);
        let b4 = lemma_f3_bound(4.0, 2.0, 16, 0.01, 4.0, 1.0);
        assert!((b4 / b1 - 16.0).abs() < 1e-9); // quadratic in H
    }
}
