//! Closed-form evaluators for the paper's convergence upper bounds —
//! used by the `table2` harness to print theory-vs-measured rows.

/// Parameters shared by both theorems.
#[derive(Clone, Copy, Debug)]
pub struct BoundParams {
    /// number of agents n
    pub n: usize,
    /// graph degree r
    pub r: f64,
    /// Laplacian spectral gap λ₂
    pub lambda2: f64,
    /// mean local steps H
    pub h: f64,
    /// smoothness L
    pub l: f64,
    /// total interactions T
    pub t: u64,
    /// f(μ₀) − f(x*)
    pub f_gap: f64,
}

/// Theorem 4.1 RHS (second-moment bound M², geometric H):
/// 4(f(μ₀)−f*)/(√T·H) + 2304·H²·max(1,L²)·M²/√T · (r²/λ₂² + 1).
pub fn theorem41_bound(p: &BoundParams, m_sq: f64) -> f64 {
    let sqrt_t = (p.t as f64).sqrt();
    let topo = p.r * p.r / (p.lambda2 * p.lambda2) + 1.0;
    4.0 * p.f_gap / (sqrt_t * p.h)
        + 2304.0 * p.h * p.h * p.l.max(1.0).powi(2) * m_sq / sqrt_t * topo
}

/// Theorem 4.2 RHS (variance σ² + heterogeneity ρ², fixed H):
/// (f(μ₀)−f*)/(√T·H) + 376·H²·max(1,L²)·(σ²+4ρ²)/√T · (r²/λ₂² + 1).
pub fn theorem42_bound(p: &BoundParams, sigma_sq: f64, rho_sq: f64) -> f64 {
    let sqrt_t = (p.t as f64).sqrt();
    let topo = p.r * p.r / (p.lambda2 * p.lambda2) + 1.0;
    p.f_gap / (sqrt_t * p.h)
        + 376.0 * p.h * p.h * p.l.max(1.0).powi(2) * (sigma_sq + 4.0 * rho_sq) / sqrt_t * topo
}

/// Theorem 4.1 admissibility: T ≥ n⁴.
pub fn theorem41_t_ok(p: &BoundParams) -> bool {
    p.t as f64 >= (p.n as f64).powi(4)
}

/// Theorem 4.2 admissibility: T ≥ 57600·n⁴H²·max(1,L²)·(r²/λ₂²+1)².
pub fn theorem42_t_ok(p: &BoundParams) -> bool {
    let topo = p.r * p.r / (p.lambda2 * p.lambda2) + 1.0;
    p.t as f64
        >= 57600.0 * (p.n as f64).powi(4) * p.h * p.h * p.l.max(1.0).powi(2) * topo * topo
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> BoundParams {
        BoundParams {
            n: 8,
            r: 7.0,
            lambda2: 8.0,
            h: 2.0,
            l: 1.0,
            t: 10_000,
            f_gap: 1.0,
        }
    }

    #[test]
    fn bound_decreases_in_t() {
        let mut p = base();
        let b1 = theorem41_bound(&p, 1.0);
        p.t = 1_000_000;
        let b2 = theorem41_bound(&p, 1.0);
        assert!(b2 < b1);
        // O(1/sqrt(T)) scaling
        assert!((b1 / b2 - 10.0).abs() < 1e-6);
    }

    #[test]
    fn first_term_benefits_from_h_second_pays_h_squared() {
        let p1 = BoundParams { h: 1.0, ..base() };
        let p4 = BoundParams { h: 4.0, ..base() };
        let sqrt_t = (p1.t as f64).sqrt();
        let first_1 = 4.0 * p1.f_gap / (sqrt_t * p1.h);
        let first_4 = 4.0 * p4.f_gap / (sqrt_t * p4.h);
        assert!((first_1 / first_4 - 4.0).abs() < 1e-9);
        // full bound grows if variance dominates
        assert!(theorem41_bound(&p4, 1.0) > theorem41_bound(&p1, 1.0));
    }

    #[test]
    fn better_connectivity_tightens_bound() {
        let ring = BoundParams { r: 2.0, lambda2: 0.1, ..base() };
        let complete = BoundParams { r: 7.0, lambda2: 8.0, ..base() };
        assert!(theorem41_bound(&complete, 1.0) < theorem41_bound(&ring, 1.0));
    }

    #[test]
    fn admissibility_thresholds() {
        let p = BoundParams { t: 4096, ..base() };
        assert!(theorem41_t_ok(&p)); // 8^4 = 4096
        let p2 = BoundParams { t: 4095, ..base() };
        assert!(!theorem41_t_ok(&p2));
        assert!(!theorem42_t_ok(&p)); // far stricter
    }

    #[test]
    fn theorem42_uses_variance_not_second_moment() {
        let p = base();
        let low_var = theorem42_bound(&p, 0.01, 0.0);
        let high_var = theorem42_bound(&p, 1.0, 0.0);
        assert!(low_var < high_var);
        let hetero = theorem42_bound(&p, 0.01, 1.0);
        assert!(hetero > low_var);
    }
}
