//! Empirical convergence-rate fitting: estimate the exponent `p` in
//! `gap(T) ≈ c · T^{-p}` from a loss curve via least squares in log–log
//! space.  Used by `table2` analysis and tests to check the paper's
//! O(1/√T) claim *quantitatively* (p ≈ 0.5 in the noise-dominated regime;
//! the noiseless quadratic contracts geometrically, i.e. p is large).

/// Least-squares slope/intercept of y = a + b·x.
fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let b = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    let a = (sy - b * sx) / n;
    (a, b)
}

/// Fit `gap(t) = c · t^{-p}` over (t, gap) samples with gap > 0.
/// Returns `(p, c, r_squared)`; `None` if fewer than 3 usable points.
pub fn fit_power_law(samples: &[(f64, f64)]) -> Option<(f64, f64, f64)> {
    let pts: Vec<(f64, f64)> = samples
        .iter()
        .filter(|&&(t, g)| t > 0.0 && g > 0.0)
        .map(|&(t, g)| (t.ln(), g.ln()))
        .collect();
    if pts.len() < 3 {
        return None;
    }
    let xs: Vec<f64> = pts.iter().map(|p| p.0).collect();
    let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
    let (a, b) = linfit(&xs, &ys);
    // R²
    let my = ys.iter().sum::<f64>() / ys.len() as f64;
    let ss_tot: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(&ys)
        .map(|(x, y)| (y - (a + b * x)).powi(2))
        .sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    Some((-b, a.exp(), r2))
}

/// Convenience: extract (t, eval_loss − f*) pairs from a run curve.
pub fn gap_samples(
    curve: &[crate::coordinator::CurvePoint],
    f_star: f64,
) -> Vec<(f64, f64)> {
    curve
        .iter()
        .map(|p| (p.t as f64, (p.eval_loss - f_star).max(0.0)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_known_exponent() {
        // gap = 3 * t^{-0.5}
        let samples: Vec<(f64, f64)> =
            (1..100).map(|t| (t as f64, 3.0 * (t as f64).powf(-0.5))).collect();
        let (p, c, r2) = fit_power_law(&samples).unwrap();
        assert!((p - 0.5).abs() < 1e-9, "p={p}");
        assert!((c - 3.0).abs() < 1e-9, "c={c}");
        assert!(r2 > 0.999999);
    }

    #[test]
    fn handles_noise() {
        let mut rng = crate::rngx::Pcg64::seed(3);
        let samples: Vec<(f64, f64)> = (10..500)
            .map(|t| {
                let g = 2.0 * (t as f64).powf(-0.7) * (1.0 + 0.1 * rng.normal());
                (t as f64, g.max(1e-12))
            })
            .collect();
        let (p, _, r2) = fit_power_law(&samples).unwrap();
        assert!((p - 0.7).abs() < 0.05, "p={p}");
        assert!(r2 > 0.9, "r2={r2}");
    }

    #[test]
    fn too_few_points() {
        assert!(fit_power_law(&[(1.0, 1.0), (2.0, 0.5)]).is_none());
        assert!(fit_power_law(&[(1.0, 0.0), (2.0, 0.0), (3.0, 0.0)]).is_none());
    }

    #[test]
    fn swarm_rate_on_noisy_quadratic_is_sublinear_power_law() {
        use crate::coordinator::{
            run_serial, AveragingMode, LocalSteps, LrSchedule, RunSpec, SwarmSgd,
        };
        use crate::grad::QuadraticOracle;
        use crate::netmodel::CostModel;
        use crate::rngx::Pcg64;
        use crate::topology::{Graph, Topology};

        let n = 8;
        let t = 16_384u64;
        let b = QuadraticOracle::new(16, n, 1.0, 0.5, 2.0, 0.5, 77);
        let f_star = b.f_star();
        let mut rng = Pcg64::seed(3);
        let graph = Graph::build(Topology::Complete, n, &mut rng);
        let cost = CostModel::deterministic(1.0);
        let algo = SwarmSgd {
            local_steps: LocalSteps::Fixed(2),
            mode: AveragingMode::NonBlocking,
        };
        let spec = RunSpec {
            n,
            events: t,
            lr: LrSchedule::Theory { n, t },
            seed: 5,
            name: "fit".into(),
            eval_every: 16, // dense early sampling: the decay is fast
            track_gamma: false,
        };
        let m = run_serial(&algo, &b, &spec, &graph, &cost);
        let samples = gap_samples(&m.curve, f_star);
        // a constant lr plateaus at its noise floor; the power-law regime is
        // the transient ABOVE the floor — fit that prefix only
        let tail = &samples[samples.len() * 3 / 4..];
        let mut floor: Vec<f64> = tail.iter().map(|s| s.1).collect();
        floor.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let floor = floor[floor.len() / 2];
        let prefix: Vec<(f64, f64)> = samples
            .iter()
            .copied()
            .take_while(|&(_, g)| g > 2.0 * floor)
            .collect();
        assert!(prefix.len() >= 4, "decay transient too short ({} pts)", prefix.len());
        let (p, _, _) = fit_power_law(&prefix).expect("enough points");
        assert!(p > 0.05, "fitted exponent {p} should be positive");
        // and decay did happen: transient start well above the floor
        assert!(prefix[0].1 > 4.0 * floor, "start {} floor {floor}", prefix[0].1);
    }
}
