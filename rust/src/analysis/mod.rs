//! Theory instrumentation: the Γ_t potential and the Theorem 4.1/4.2 bound
//! evaluators (DESIGN.md S19).

mod bounds;
mod gamma;
mod ratefit;

pub use bounds::{theorem41_bound, theorem41_t_ok, theorem42_bound, theorem42_t_ok, BoundParams};
pub use gamma::{gamma_potential, lemma_f3_bound, mean_model, GammaTracker};
pub use ratefit::{fit_power_law, gap_samples};
