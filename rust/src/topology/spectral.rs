//! Dense symmetric eigensolver (cyclic Jacobi) for Laplacian spectra.
//!
//! The paper's rates carry a `(r²/λ₂² + 1)` factor; the figure harnesses and
//! the theory-bound evaluators need λ₂ for each topology. n ≤ a few hundred
//! in every experiment, so an O(n³) dense Jacobi sweep is plenty — and it is
//! provably convergent on symmetric matrices, with no external deps.

use super::Graph;

/// Row-major dense Laplacian L = D − A of `g`, built per edge incidence —
/// identical to the degree form for undirected graphs, and for directed
/// graphs the symmetrized (A + Aᵀ) Laplacian, so the Jacobi solver always
/// sees a symmetric matrix.
pub fn laplacian(g: &Graph) -> Vec<f64> {
    let n = g.n();
    let mut l = vec![0.0; n * n];
    for &(u, v) in g.edges() {
        l[u * n + u] += 1.0;
        l[v * n + v] += 1.0;
        l[u * n + v] -= 1.0;
        l[v * n + u] -= 1.0;
    }
    l
}

/// All eigenvalues of a symmetric matrix (row-major, n×n), ascending.
/// Cyclic Jacobi with threshold sweeps; converges quadratically.
pub fn jacobi_eigenvalues(a: &[f64], n: usize) -> Vec<f64> {
    assert_eq!(a.len(), n * n);
    let mut m = a.to_vec();
    // symmetry check (debug builds only)
    #[cfg(debug_assertions)]
    for i in 0..n {
        for j in 0..n {
            debug_assert!(
                (m[i * n + j] - m[j * n + i]).abs() < 1e-9,
                "matrix not symmetric at ({i},{j})"
            );
        }
    }
    let max_sweeps = 100;
    for _sweep in 0..max_sweeps {
        // off-diagonal Frobenius norm
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[i * n + j] * m[i * n + j];
            }
        }
        if off.sqrt() < 1e-12 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                if apq.abs() < 1e-14 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p and q
                for k in 0..n {
                    let akp = m[k * n + p];
                    let akq = m[k * n + q];
                    m[k * n + p] = c * akp - s * akq;
                    m[k * n + q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = m[p * n + k];
                    let aqk = m[q * n + k];
                    m[p * n + k] = c * apk - s * aqk;
                    m[q * n + k] = s * apk + c * aqk;
                }
            }
        }
    }
    let mut eig: Vec<f64> = (0..n).map(|i| m[i * n + i]).collect();
    eig.sort_by(|a, b| a.partial_cmp(b).unwrap());
    eig
}

/// λ₂ — second-smallest Laplacian eigenvalue of `g`.
///
/// Disconnected graphs (including directed graphs that are not *strongly*
/// connected) return exactly 0.0 rather than whatever tiny or garbage
/// eigenvalue the numerical solve produces — λ₂ = 0 iff disconnected is the
/// theorem, so the code states it. Single-node graphs have no λ₂; they
/// also report 0.0.
pub fn spectral_gap(g: &Graph) -> f64 {
    let n = g.n();
    if n < 2 || !g.is_connected() {
        return 0.0;
    }
    let l = laplacian(g);
    let eig = jacobi_eigenvalues(&l, n);
    eig[1].max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Pcg64;
    use crate::topology::Topology;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() < tol
    }

    #[test]
    fn complete_graph_lambda2_is_n() {
        for n in [4, 8, 16] {
            let g = Graph::complete(n);
            assert!(
                close(g.lambda2(), n as f64, 1e-8),
                "K_{n}: λ₂={}",
                g.lambda2()
            );
        }
    }

    #[test]
    fn ring_lambda2_closed_form() {
        for n in [4usize, 8, 16, 32] {
            let g = Graph::ring(n);
            let expect = 2.0 * (1.0 - (std::f64::consts::TAU / n as f64).cos());
            assert!(
                close(g.lambda2(), expect, 1e-8),
                "C_{n}: λ₂={} expect={expect}",
                g.lambda2()
            );
        }
    }

    #[test]
    fn hypercube_lambda2_is_two() {
        for n in [8, 16, 32] {
            let g = Graph::hypercube(n);
            assert!(close(g.lambda2(), 2.0, 1e-8), "Q: λ₂={}", g.lambda2());
        }
    }

    #[test]
    fn torus_lambda2_closed_form() {
        // λ₂(C_s □ C_s) = λ₂(C_s) = 2(1 − cos 2π/s)
        let g = Graph::torus(25);
        let expect = 2.0 * (1.0 - (std::f64::consts::TAU / 5.0).cos());
        assert!(close(g.lambda2(), expect, 1e-8), "λ₂={}", g.lambda2());
    }

    #[test]
    fn smallest_eigenvalue_is_zero() {
        let g = Graph::torus(16);
        let eig = jacobi_eigenvalues(&laplacian(&g), 16);
        assert!(eig[0].abs() < 1e-9);
        // connected => λ₂ > 0
        assert!(eig[1] > 1e-9);
    }

    #[test]
    fn eigenvalue_sum_equals_trace() {
        let mut rng = Pcg64::seed(5);
        let g = Graph::build(Topology::RandomRegular(4), 20, &mut rng);
        let l = laplacian(&g);
        let eig = jacobi_eigenvalues(&l, 20);
        let trace: f64 = (0..20).map(|i| l[i * 20 + i]).sum();
        assert!(close(eig.iter().sum::<f64>(), trace, 1e-6));
        // trace of Laplacian = sum of degrees = 2|E|
        assert!(close(trace, 80.0, 1e-12));
    }

    #[test]
    fn random_regular_connected_gap_positive() {
        let mut rng = Pcg64::seed(7);
        for _ in 0..5 {
            let g = Graph::random_regular(24, 4, &mut rng);
            assert!(g.lambda2() > 0.05, "λ₂={}", g.lambda2());
        }
    }

    #[test]
    fn disconnected_graph_gap_is_exactly_zero() {
        // two disjoint triangles
        let g = Graph::from_edges(6, vec![(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3)]);
        assert!(!g.is_connected());
        assert_eq!(g.lambda2(), 0.0);
        // a weakly- but not strongly-connected directed graph is "not
        // connected" for gossip purposes: gap is zero too
        let d = Graph::from_arcs(3, vec![(0, 1), (1, 2)]);
        assert_eq!(d.lambda2(), 0.0);
        // single node: no λ₂ to report
        assert_eq!(Graph::complete(1).lambda2(), 0.0);
    }

    #[test]
    fn directed_ring_gap_matches_symmetrized_undirected_ring() {
        let expect = 2.0 * (1.0 - (std::f64::consts::TAU / 8.0).cos());
        assert!(close(Graph::directed_ring(8).lambda2(), expect, 1e-8));
    }

    #[test]
    fn jacobi_on_known_matrix() {
        // [[2,1],[1,2]] has eigenvalues {1, 3}
        let eig = jacobi_eigenvalues(&[2.0, 1.0, 1.0, 2.0], 2);
        assert!(close(eig[0], 1.0, 1e-12) && close(eig[1], 3.0, 1e-12));
    }
}
