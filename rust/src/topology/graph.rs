//! Graph construction: the regular topologies the paper's model assumes.

use crate::rngx::Pcg64;

/// Named topology families. All are `r`-regular and connected (the random
/// regular family retries until connected).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Complete graph K_n — the paper's experimental overlay ("fully
    /// connected with random pairings"); λ₂ = n.
    Complete,
    /// Cycle C_n; λ₂ = 2(1 − cos 2π/n). Worst-case connectivity.
    Ring,
    /// √n × √n torus (requires square n); 4-regular.
    Torus,
    /// Hypercube Q_d (requires n = 2^d); log₂n-regular, λ₂ = 2.
    Hypercube,
    /// Random r-regular graph via the pairing model (connected by retry).
    RandomRegular(usize),
}

/// Undirected simple graph stored as an edge list + adjacency lists.
#[derive(Clone, Debug)]
pub struct Graph {
    n: usize,
    edges: Vec<(usize, usize)>,
    adj: Vec<Vec<usize>>,
}

impl Graph {
    pub fn build(topo: Topology, n: usize, rng: &mut Pcg64) -> Self {
        match topo {
            Topology::Complete => Self::complete(n),
            Topology::Ring => Self::ring(n),
            Topology::Torus => Self::torus(n),
            Topology::Hypercube => Self::hypercube(n),
            Topology::RandomRegular(r) => Self::random_regular(n, r, rng),
        }
    }

    pub fn from_edges(n: usize, edges: Vec<(usize, usize)>) -> Self {
        let mut adj = vec![Vec::new(); n];
        for &(u, v) in &edges {
            assert!(u < n && v < n && u != v, "bad edge ({u},{v}) for n={n}");
            adj[u].push(v);
            adj[v].push(u);
        }
        Self { n, edges, adj }
    }

    pub fn complete(n: usize) -> Self {
        assert!(n >= 1, "complete graph needs n >= 1");
        // n == 1 yields an edgeless single-node graph (valid for the
        // single-node SGD baseline; gossip algorithms never sample from it)
        let mut edges = Vec::with_capacity(n * (n - 1) / 2);
        for u in 0..n {
            for v in (u + 1)..n {
                edges.push((u, v));
            }
        }
        Self::from_edges(n, edges)
    }

    pub fn ring(n: usize) -> Self {
        assert!(n >= 3, "ring needs n >= 3");
        let edges = (0..n).map(|u| (u, (u + 1) % n)).collect();
        Self::from_edges(n, edges)
    }

    pub fn torus(n: usize) -> Self {
        let side = (n as f64).sqrt().round() as usize;
        assert_eq!(side * side, n, "torus needs square n, got {n}");
        assert!(side >= 3, "torus needs side >= 3 for simple graph");
        let mut edges = Vec::new();
        for r in 0..side {
            for c in 0..side {
                let u = r * side + c;
                edges.push((u, r * side + (c + 1) % side));
                edges.push((u, ((r + 1) % side) * side + c));
            }
        }
        Self::from_edges(n, edges)
    }

    pub fn hypercube(n: usize) -> Self {
        assert!(n >= 2 && n.is_power_of_two(), "hypercube needs n = 2^d");
        let d = n.trailing_zeros() as usize;
        let mut edges = Vec::new();
        for u in 0..n {
            for b in 0..d {
                let v = u ^ (1 << b);
                if u < v {
                    edges.push((u, v));
                }
            }
        }
        Self::from_edges(n, edges)
    }

    /// Random r-regular graph as a union of random Hamiltonian cycles
    /// (+ one random perfect matching when r is odd; requires even n then).
    /// Always connected (every graph contains a Ham cycle); each component
    /// is resampled if it would duplicate an existing edge, which succeeds
    /// quickly for r « n.
    pub fn random_regular(n: usize, r: usize, rng: &mut Pcg64) -> Self {
        assert!(r >= 2 && r < n, "need 2 <= r < n");
        assert!(n * r % 2 == 0, "need n*r even");
        assert!(
            r % 2 == 0 || n % 2 == 0,
            "odd r needs even n for the matching layer"
        );
        let mut seen = std::collections::HashSet::new();
        let mut edges: Vec<(usize, usize)> = Vec::with_capacity(n * r / 2);
        let add_all = |cand: &[(usize, usize)],
                           seen: &mut std::collections::HashSet<(usize, usize)>,
                           edges: &mut Vec<(usize, usize)>|
         -> bool {
            let keys: Vec<(usize, usize)> =
                cand.iter().map(|&(u, v)| (u.min(v), u.max(v))).collect();
            if keys.iter().any(|k| seen.contains(k) || k.0 == k.1) {
                return false;
            }
            // also reject duplicates within the candidate set itself
            let mut s = keys.clone();
            s.sort_unstable();
            s.dedup();
            if s.len() != keys.len() {
                return false;
            }
            seen.extend(keys);
            edges.extend_from_slice(cand);
            true
        };
        // r/2 Hamiltonian cycles
        for _layer in 0..r / 2 {
            let mut ok = false;
            for _attempt in 0..10_000 {
                let mut perm: Vec<usize> = (0..n).collect();
                rng.shuffle(&mut perm);
                let cand: Vec<(usize, usize)> =
                    (0..n).map(|i| (perm[i], perm[(i + 1) % n])).collect();
                if add_all(&cand, &mut seen, &mut edges) {
                    ok = true;
                    break;
                }
            }
            assert!(ok, "random_regular({n},{r}): cycle layer failed");
        }
        // one matching layer if r is odd
        if r % 2 == 1 {
            let mut ok = false;
            for _attempt in 0..10_000 {
                let mut perm: Vec<usize> = (0..n).collect();
                rng.shuffle(&mut perm);
                let cand: Vec<(usize, usize)> =
                    perm.chunks(2).map(|c| (c[0], c[1])).collect();
                if add_all(&cand, &mut seen, &mut edges) {
                    ok = true;
                    break;
                }
            }
            assert!(ok, "random_regular({n},{r}): matching layer failed");
        }
        let g = Self::from_edges(n, edges);
        debug_assert!(g.is_connected());
        g
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn edges(&self) -> &[(usize, usize)] {
        &self.edges
    }

    pub fn neighbors(&self, u: usize) -> &[usize] {
        &self.adj[u]
    }

    pub fn degree(&self, u: usize) -> usize {
        self.adj[u].len()
    }

    /// Degree if regular, else None.
    pub fn regular_degree(&self) -> Option<usize> {
        let d = self.degree(0);
        (1..self.n).all(|u| self.degree(u) == d).then_some(d)
    }

    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = stack.pop() {
            for &v in &self.adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    stack.push(v);
                }
            }
        }
        count == self.n
    }

    /// Sample an edge uniformly at random — one "step" of the paper's model.
    #[inline]
    pub fn sample_edge(&self, rng: &mut Pcg64) -> (usize, usize) {
        self.edges[rng.below_usize(self.edges.len())]
    }

    /// Sample a uniform random neighbor of `u`.
    #[inline]
    pub fn sample_neighbor(&self, u: usize, rng: &mut Pcg64) -> usize {
        self.adj[u][rng.below_usize(self.adj[u].len())]
    }

    /// Random perfect/near-perfect matching on G (used by D-PSGD rounds):
    /// greedy over a shuffled edge list.
    pub fn random_matching(&self, rng: &mut Pcg64) -> Vec<(usize, usize)> {
        let mut order: Vec<usize> = (0..self.edges.len()).collect();
        rng.shuffle(&mut order);
        let mut used = vec![false; self.n];
        let mut m = Vec::with_capacity(self.n / 2);
        for i in order {
            let (u, v) = self.edges[i];
            if !used[u] && !used[v] {
                used[u] = true;
                used[v] = true;
                m.push((u, v));
            }
        }
        m
    }

    /// λ₂ of the Laplacian (delegates to the Jacobi eigensolver).
    pub fn lambda2(&self) -> f64 {
        super::spectral::spectral_gap(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Pcg64 {
        Pcg64::seed(0xC0FFEE)
    }

    #[test]
    fn complete_graph_properties() {
        let g = Graph::complete(8);
        assert_eq!(g.edges().len(), 28);
        assert_eq!(g.regular_degree(), Some(7));
        assert!(g.is_connected());
    }

    #[test]
    fn ring_properties() {
        let g = Graph::ring(10);
        assert_eq!(g.edges().len(), 10);
        assert_eq!(g.regular_degree(), Some(2));
        assert!(g.is_connected());
    }

    #[test]
    fn torus_properties() {
        let g = Graph::torus(16);
        assert_eq!(g.regular_degree(), Some(4));
        assert_eq!(g.edges().len(), 32);
        assert!(g.is_connected());
    }

    #[test]
    fn hypercube_properties() {
        let g = Graph::hypercube(16);
        assert_eq!(g.regular_degree(), Some(4));
        assert_eq!(g.edges().len(), 32);
        assert!(g.is_connected());
    }

    #[test]
    fn random_regular_is_regular_and_connected() {
        let mut r = rng();
        for (n, d) in [(10, 3), (16, 4), (32, 6)] {
            let g = Graph::random_regular(n, d, &mut r);
            assert_eq!(g.regular_degree(), Some(d), "n={n} d={d}");
            assert!(g.is_connected());
            assert_eq!(g.edges().len(), n * d / 2);
        }
    }

    #[test]
    fn sample_edge_covers_graph() {
        let g = Graph::ring(6);
        let mut r = rng();
        let mut hit = std::collections::HashSet::new();
        for _ in 0..1000 {
            hit.insert(g.sample_edge(&mut r));
        }
        assert_eq!(hit.len(), 6);
    }

    #[test]
    fn matching_is_valid() {
        let g = Graph::complete(12);
        let mut r = rng();
        for _ in 0..50 {
            let m = g.random_matching(&mut r);
            let mut used = std::collections::HashSet::new();
            for (u, v) in &m {
                assert!(used.insert(*u));
                assert!(used.insert(*v));
            }
            // complete graph: greedy always achieves a perfect matching
            assert_eq!(m.len(), 6);
        }
    }

    #[test]
    #[should_panic]
    fn torus_rejects_non_square() {
        Graph::torus(10);
    }
}
