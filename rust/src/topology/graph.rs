//! Graph construction: the regular topologies the paper's model assumes,
//! the power-law (preferential-attachment) family, and the directed
//! orientations SGP's push-sum payload supports.

use crate::rngx::Pcg64;

/// Named topology families. The regular families are connected by
/// construction (the random regular family retries until connected); the
/// power-law family grows from a seed clique, so it is connected too.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Complete graph K_n — the paper's experimental overlay ("fully
    /// connected with random pairings"); λ₂ = n.
    Complete,
    /// Cycle C_n; λ₂ = 2(1 − cos 2π/n). Worst-case connectivity.
    Ring,
    /// √n × √n torus (requires square n); 4-regular.
    Torus,
    /// Hypercube Q_d (requires n = 2^d); log₂n-regular, λ₂ = 2.
    Hypercube,
    /// Random r-regular graph via the pairing model (connected by retry).
    RandomRegular(usize),
    /// Random r-regular **expander** (default r=8): re-sampled until the
    /// Laplacian gap clears the pinned Alon–Boppana-style lower bound
    /// [`Graph::expander_gap_bound`]. The O(n³) spectral certificate runs
    /// at sizes up to [`Graph::EXPANDER_CHECK_MAX`]; larger instances rely
    /// on random regular graphs being near-Ramanujan w.h.p. (Friedman).
    Expander(usize),
    /// Barabási–Albert preferential attachment: each new node attaches to
    /// `m` distinct existing nodes with probability ∝ degree, grown from a
    /// connected (m+1)-clique — hub-heavy degree distribution, connected
    /// by construction.
    PowerLaw(usize),
}

impl Topology {
    /// Parse a topology name: `complete | ring | torus | hypercube |
    /// random<r> | regular<r> | expander | expander<r> | powerlaw |
    /// powerlaw<m>` (`regular<r>` is an alias of `random<r>`; bare
    /// `expander` is 8-regular; bare `powerlaw` attaches with m=2).
    pub fn parse(name: &str) -> Result<Self, String> {
        let degree = |t: &str, prefix: &str| -> Result<usize, String> {
            t[prefix.len()..]
                .parse()
                .map_err(|_| format!("bad topology '{t}' (want e.g. {prefix}4)"))
        };
        Ok(match name {
            "complete" => Topology::Complete,
            "ring" => Topology::Ring,
            "torus" => Topology::Torus,
            "hypercube" => Topology::Hypercube,
            "powerlaw" => Topology::PowerLaw(2),
            "expander" => Topology::Expander(8),
            t if t.starts_with("random") => Topology::RandomRegular(degree(t, "random")?),
            t if t.starts_with("regular") => Topology::RandomRegular(degree(t, "regular")?),
            t if t.starts_with("powerlaw") => Topology::PowerLaw(degree(t, "powerlaw")?),
            t if t.starts_with("expander") => Topology::Expander(degree(t, "expander")?),
            t => {
                return Err(format!(
                    "unknown topology '{t}' (known: complete, ring, torus, \
                     hypercube, random<r>/regular<r>, expander[<r>], \
                     powerlaw[<m>])"
                ))
            }
        })
    }

    /// Feasibility of this family at `n` nodes — the config-path twin of
    /// the constructor asserts, returning actionable errors instead of
    /// panicking.
    pub fn validate(self, n: usize) -> Result<(), String> {
        match self {
            Topology::Complete => {
                if n < 1 {
                    return Err("complete topology needs n >= 1".into());
                }
            }
            Topology::Ring => {
                if n < 3 {
                    return Err(format!("ring topology needs n >= 3, got n={n}"));
                }
            }
            Topology::Torus => {
                let side = (n as f64).sqrt().round() as usize;
                if side * side != n || side < 3 {
                    return Err(format!(
                        "torus topology needs a square n with side >= 3; n={n} is \
                         not (nearest: {} or {})",
                        side.max(3) * side.max(3),
                        (side + 1) * (side + 1)
                    ));
                }
            }
            Topology::Hypercube => {
                if n < 2 || !n.is_power_of_two() {
                    return Err(format!(
                        "hypercube topology needs n = 2^d (d >= 1); n={n} is not \
                         a power of two (nearest: {} or {})",
                        (n.max(2)).next_power_of_two() / 2,
                        n.max(2).next_power_of_two()
                    ));
                }
            }
            Topology::RandomRegular(r) => {
                if r < 2 || r >= n {
                    return Err(format!(
                        "regular topology needs degree 2 <= r < n, got r={r} n={n}"
                    ));
                }
                if n * r % 2 != 0 {
                    return Err(format!(
                        "regular topology needs n*r even (every graph has an even \
                         degree sum); n={n} r={r} gives n*r={}",
                        n * r
                    ));
                }
            }
            Topology::PowerLaw(m) => {
                if m < 1 || n < m + 2 {
                    return Err(format!(
                        "powerlaw topology needs attachment degree m >= 1 and \
                         n >= m+2 (an (m+1)-clique seed plus at least one \
                         attached node), got m={m} n={n}"
                    ));
                }
            }
            Topology::Expander(r) => {
                if r < 3 || r >= n {
                    return Err(format!(
                        "expander topology needs degree 3 <= r < n, got r={r} n={n}"
                    ));
                }
                if n * r % 2 != 0 {
                    return Err(format!(
                        "expander topology needs n*r even (every graph has an \
                         even degree sum); n={n} r={r} gives n*r={} — use an \
                         even degree (e.g. expander{}) or an even n",
                        n * r,
                        r + 1
                    ));
                }
            }
        }
        Ok(())
    }
}

/// Simple graph stored as an edge list + adjacency lists.
///
/// Undirected by default (every constructor except [`Graph::from_arcs`] and
/// the `directed_*` orientations): `edges` holds each pair once and `adj`
/// mirrors both directions. Directed graphs store arcs `(src, dst)` and
/// `adj[u]` holds **out**-neighbors only, so [`Graph::sample_neighbor`]
/// samples along arc direction — the push-sum (SGP) send direction.
#[derive(Clone, Debug)]
pub struct Graph {
    n: usize,
    edges: Vec<(usize, usize)>,
    adj: Vec<Vec<usize>>,
    directed: bool,
}

impl Graph {
    pub fn build(topo: Topology, n: usize, rng: &mut Pcg64) -> Self {
        match topo {
            Topology::Complete => Self::complete(n),
            Topology::Ring => Self::ring(n),
            Topology::Torus => Self::torus(n),
            Topology::Hypercube => Self::hypercube(n),
            Topology::RandomRegular(r) => Self::random_regular(n, r, rng),
            Topology::PowerLaw(m) => Self::power_law(n, m, rng),
            Topology::Expander(r) => Self::expander(n, r, rng),
        }
    }

    /// Build the directed orientation of `topo` (ring and torus have
    /// canonical rotor orientations; complete is symmetric, so its directed
    /// form keeps all ordered pairs). Other families have no canonical
    /// orientation — the config layer rejects them before reaching here.
    pub fn build_directed(topo: Topology, n: usize) -> Self {
        match topo {
            Topology::Complete => {
                let mut arcs = Vec::with_capacity(n * (n - 1));
                for u in 0..n {
                    for v in 0..n {
                        if u != v {
                            arcs.push((u, v));
                        }
                    }
                }
                Self::from_arcs(n, arcs)
            }
            Topology::Ring => Self::directed_ring(n),
            Topology::Torus => Self::directed_torus(n),
            t => panic!("no canonical directed orientation for {t:?}"),
        }
    }

    pub fn from_edges(n: usize, edges: Vec<(usize, usize)>) -> Self {
        let mut adj = vec![Vec::new(); n];
        for &(u, v) in &edges {
            assert!(u < n && v < n && u != v, "bad edge ({u},{v}) for n={n}");
            adj[u].push(v);
            adj[v].push(u);
        }
        Self { n, edges, adj, directed: false }
    }

    /// Directed graph from an arc list `(src, dst)`; `adj` holds
    /// out-neighbors only.
    pub fn from_arcs(n: usize, arcs: Vec<(usize, usize)>) -> Self {
        let mut adj = vec![Vec::new(); n];
        for &(u, v) in &arcs {
            assert!(u < n && v < n && u != v, "bad arc ({u},{v}) for n={n}");
            adj[u].push(v);
        }
        Self { n, edges: arcs, adj, directed: true }
    }

    pub fn complete(n: usize) -> Self {
        assert!(n >= 1, "complete graph needs n >= 1");
        // n == 1 yields an edgeless single-node graph (valid for the
        // single-node SGD baseline; gossip algorithms never sample from it)
        let mut edges = Vec::with_capacity(n * (n - 1) / 2);
        for u in 0..n {
            for v in (u + 1)..n {
                edges.push((u, v));
            }
        }
        Self::from_edges(n, edges)
    }

    pub fn ring(n: usize) -> Self {
        assert!(n >= 3, "ring needs n >= 3");
        let edges = (0..n).map(|u| (u, (u + 1) % n)).collect();
        Self::from_edges(n, edges)
    }

    /// Directed cycle u → u+1 (mod n): the canonical strongly-connected
    /// rotor for push-sum.
    pub fn directed_ring(n: usize) -> Self {
        assert!(n >= 3, "ring needs n >= 3");
        let arcs = (0..n).map(|u| (u, (u + 1) % n)).collect();
        Self::from_arcs(n, arcs)
    }

    pub fn torus(n: usize) -> Self {
        let side = (n as f64).sqrt().round() as usize;
        assert_eq!(side * side, n, "torus needs square n, got {n}");
        assert!(side >= 3, "torus needs side >= 3 for simple graph");
        let mut edges = Vec::new();
        for r in 0..side {
            for c in 0..side {
                let u = r * side + c;
                edges.push((u, r * side + (c + 1) % side));
                edges.push((u, ((r + 1) % side) * side + c));
            }
        }
        Self::from_edges(n, edges)
    }

    /// Directed torus: right + down arcs only (each node out-degree 2) —
    /// strongly connected, the 2-D rotor orientation.
    pub fn directed_torus(n: usize) -> Self {
        let side = (n as f64).sqrt().round() as usize;
        assert_eq!(side * side, n, "torus needs square n, got {n}");
        assert!(side >= 3, "torus needs side >= 3 for simple graph");
        let mut arcs = Vec::new();
        for r in 0..side {
            for c in 0..side {
                let u = r * side + c;
                arcs.push((u, r * side + (c + 1) % side));
                arcs.push((u, ((r + 1) % side) * side + c));
            }
        }
        Self::from_arcs(n, arcs)
    }

    pub fn hypercube(n: usize) -> Self {
        assert!(n >= 2 && n.is_power_of_two(), "hypercube needs n = 2^d");
        let d = n.trailing_zeros() as usize;
        let mut edges = Vec::new();
        for u in 0..n {
            for b in 0..d {
                let v = u ^ (1 << b);
                if u < v {
                    edges.push((u, v));
                }
            }
        }
        Self::from_edges(n, edges)
    }

    /// Random r-regular graph as a union of random Hamiltonian cycles
    /// (+ one random perfect matching when r is odd; requires even n then).
    /// Always connected (every graph contains a Ham cycle); each component
    /// is resampled if it would duplicate an existing edge, which succeeds
    /// quickly for r « n.
    pub fn random_regular(n: usize, r: usize, rng: &mut Pcg64) -> Self {
        assert!(r >= 2 && r < n, "need 2 <= r < n");
        assert!(n * r % 2 == 0, "need n*r even");
        assert!(
            r % 2 == 0 || n % 2 == 0,
            "odd r needs even n for the matching layer"
        );
        let mut seen = std::collections::HashSet::new();
        let mut edges: Vec<(usize, usize)> = Vec::with_capacity(n * r / 2);
        let add_all = |cand: &[(usize, usize)],
                           seen: &mut std::collections::HashSet<(usize, usize)>,
                           edges: &mut Vec<(usize, usize)>|
         -> bool {
            let keys: Vec<(usize, usize)> =
                cand.iter().map(|&(u, v)| (u.min(v), u.max(v))).collect();
            if keys.iter().any(|k| seen.contains(k) || k.0 == k.1) {
                return false;
            }
            // also reject duplicates within the candidate set itself
            let mut s = keys.clone();
            s.sort_unstable();
            s.dedup();
            if s.len() != keys.len() {
                return false;
            }
            seen.extend(keys);
            edges.extend_from_slice(cand);
            true
        };
        // r/2 Hamiltonian cycles
        for _layer in 0..r / 2 {
            let mut ok = false;
            for _attempt in 0..10_000 {
                let mut perm: Vec<usize> = (0..n).collect();
                rng.shuffle(&mut perm);
                let cand: Vec<(usize, usize)> =
                    (0..n).map(|i| (perm[i], perm[(i + 1) % n])).collect();
                if add_all(&cand, &mut seen, &mut edges) {
                    ok = true;
                    break;
                }
            }
            assert!(ok, "random_regular({n},{r}): cycle layer failed");
        }
        // one matching layer if r is odd
        if r % 2 == 1 {
            let mut ok = false;
            for _attempt in 0..10_000 {
                let mut perm: Vec<usize> = (0..n).collect();
                rng.shuffle(&mut perm);
                let cand: Vec<(usize, usize)> =
                    perm.chunks(2).map(|c| (c[0], c[1])).collect();
                if add_all(&cand, &mut seen, &mut edges) {
                    ok = true;
                    break;
                }
            }
            assert!(ok, "random_regular({n},{r}): matching layer failed");
        }
        let g = Self::from_edges(n, edges);
        debug_assert!(g.is_connected());
        g
    }

    /// Largest n at which [`Graph::expander`] runs its O(n³) spectral
    /// certificate; larger instances rely on the w.h.p. guarantee.
    pub const EXPANDER_CHECK_MAX: usize = 256;

    /// The pinned Laplacian-gap lower bound an expander sample must clear:
    /// `r − 2.2·√(r−1)`. Alon–Boppana caps the adjacency gap of any
    /// r-regular graph at `r − 2√(r−1) − o(1)`, and random regular graphs
    /// get within any ε of it w.h.p. (Friedman), so the 2.2 slack makes
    /// the certificate pass after few retries while still rejecting
    /// near-bipartite or badly-clustered samples. For the default r=8
    /// this demands λ₂ ≥ 2.18 — far above ring (λ₂ → 0) at equal n.
    pub fn expander_gap_bound(r: usize) -> f64 {
        (r as f64 - 2.2 * ((r.max(1) - 1) as f64).sqrt()).max(0.0)
    }

    /// Random r-regular expander: [`Graph::random_regular`] re-sampled
    /// until λ₂ clears [`Graph::expander_gap_bound`]. The certificate is
    /// checked up to [`Graph::EXPANDER_CHECK_MAX`] nodes (the eigensolver
    /// is O(n³)); beyond that a single sample is returned unchecked.
    pub fn expander(n: usize, r: usize, rng: &mut Pcg64) -> Self {
        assert!(r >= 3 && r < n, "expander needs 3 <= r < n");
        if n > Self::EXPANDER_CHECK_MAX {
            return Self::random_regular(n, r, rng);
        }
        let bound = Self::expander_gap_bound(r);
        let mut g = Self::random_regular(n, r, rng);
        for _ in 0..16 {
            if g.lambda2() >= bound {
                return g;
            }
            g = Self::random_regular(n, r, rng);
        }
        panic!(
            "expander({n},{r}): no sample cleared the λ₂ >= {bound:.3} \
             certificate in 16 draws"
        );
    }

    /// Barabási–Albert preferential attachment: start from a complete
    /// graph on `m+1` nodes, then attach each node `t` in `m+1..n` to `m`
    /// distinct earlier nodes drawn with probability ∝ current degree
    /// (sampled from the edge-endpoint multiset, with rejection for
    /// distinctness). Connected by construction: every node links into the
    /// connected seed component.
    pub fn power_law(n: usize, m: usize, rng: &mut Pcg64) -> Self {
        assert!(m >= 1 && n >= m + 2, "powerlaw needs m >= 1 and n >= m+2");
        let mut edges: Vec<(usize, usize)> = Vec::new();
        // each endpoint appears once per incident edge — sampling an entry
        // uniformly IS degree-proportional sampling
        let mut endpoints: Vec<usize> = Vec::with_capacity(2 * n * m);
        for u in 0..=m {
            for v in (u + 1)..=m {
                edges.push((u, v));
                endpoints.push(u);
                endpoints.push(v);
            }
        }
        for t in (m + 1)..n {
            let mut targets: Vec<usize> = Vec::with_capacity(m);
            while targets.len() < m {
                let v = endpoints[rng.below_usize(endpoints.len())];
                if !targets.contains(&v) {
                    targets.push(v);
                }
            }
            for &v in &targets {
                edges.push((t, v));
                endpoints.push(t);
                endpoints.push(v);
            }
        }
        let g = Self::from_edges(n, edges);
        debug_assert!(g.is_connected());
        g
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Edge pairs for undirected graphs; arcs `(src, dst)` for directed.
    pub fn edges(&self) -> &[(usize, usize)] {
        &self.edges
    }

    pub fn is_directed(&self) -> bool {
        self.directed
    }

    /// Neighbors of `u` (out-neighbors for directed graphs).
    pub fn neighbors(&self, u: usize) -> &[usize] {
        &self.adj[u]
    }

    /// Degree of `u` (out-degree for directed graphs).
    pub fn degree(&self, u: usize) -> usize {
        self.adj[u].len()
    }

    /// Degree if regular, else None.
    pub fn regular_degree(&self) -> Option<usize> {
        let d = self.degree(0);
        (1..self.n).all(|u| self.degree(u) == d).then_some(d)
    }

    fn reaches_all(&self, adj: &[Vec<usize>]) -> bool {
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = stack.pop() {
            for &v in &adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    stack.push(v);
                }
            }
        }
        count == self.n
    }

    /// Connectivity: plain connectivity for undirected graphs, **strong**
    /// connectivity for directed ones (forward and reverse reachability
    /// from node 0 — the condition push-sum needs to mix).
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        if !self.reaches_all(&self.adj) {
            return false;
        }
        if self.directed {
            let mut rev = vec![Vec::new(); self.n];
            for &(u, v) in &self.edges {
                rev[v].push(u);
            }
            return self.reaches_all(&rev);
        }
        true
    }

    /// Sample an edge uniformly at random — one "step" of the paper's model.
    /// Undirected only: a symmetric gossip pair has no arc orientation.
    #[inline]
    pub fn sample_edge(&self, rng: &mut Pcg64) -> (usize, usize) {
        assert!(!self.directed, "sample_edge needs an undirected graph");
        self.edges[rng.below_usize(self.edges.len())]
    }

    /// Sample a uniform random neighbor of `u` (out-neighbor if directed).
    #[inline]
    pub fn sample_neighbor(&self, u: usize, rng: &mut Pcg64) -> usize {
        self.adj[u][rng.below_usize(self.adj[u].len())]
    }

    /// Random perfect/near-perfect matching on G (used by D-PSGD rounds):
    /// greedy over a shuffled edge list. Undirected only.
    pub fn random_matching(&self, rng: &mut Pcg64) -> Vec<(usize, usize)> {
        assert!(!self.directed, "random_matching needs an undirected graph");
        let mut order: Vec<usize> = (0..self.edges.len()).collect();
        rng.shuffle(&mut order);
        let mut used = vec![false; self.n];
        let mut m = Vec::with_capacity(self.n / 2);
        for i in order {
            let (u, v) = self.edges[i];
            if !used[u] && !used[v] {
                used[u] = true;
                used[v] = true;
                m.push((u, v));
            }
        }
        m
    }

    /// λ₂ of the Laplacian (delegates to the Jacobi eigensolver).
    pub fn lambda2(&self) -> f64 {
        super::spectral::spectral_gap(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Pcg64 {
        Pcg64::seed(0xC0FFEE)
    }

    #[test]
    fn complete_graph_properties() {
        let g = Graph::complete(8);
        assert_eq!(g.edges().len(), 28);
        assert_eq!(g.regular_degree(), Some(7));
        assert!(g.is_connected());
        assert!(!g.is_directed());
    }

    #[test]
    fn ring_properties() {
        let g = Graph::ring(10);
        assert_eq!(g.edges().len(), 10);
        assert_eq!(g.regular_degree(), Some(2));
        assert!(g.is_connected());
    }

    #[test]
    fn torus_properties() {
        let g = Graph::torus(16);
        assert_eq!(g.regular_degree(), Some(4));
        assert_eq!(g.edges().len(), 32);
        assert!(g.is_connected());
    }

    #[test]
    fn hypercube_properties() {
        let g = Graph::hypercube(16);
        assert_eq!(g.regular_degree(), Some(4));
        assert_eq!(g.edges().len(), 32);
        assert!(g.is_connected());
    }

    #[test]
    fn random_regular_is_regular_and_connected() {
        let mut r = rng();
        for (n, d) in [(10, 3), (16, 4), (32, 6)] {
            let g = Graph::random_regular(n, d, &mut r);
            assert_eq!(g.regular_degree(), Some(d), "n={n} d={d}");
            assert!(g.is_connected());
            assert_eq!(g.edges().len(), n * d / 2);
        }
    }

    #[test]
    fn power_law_is_connected_with_exact_edge_count() {
        let mut r = rng();
        for (n, m) in [(16, 1), (40, 2), (64, 3)] {
            let g = Graph::power_law(n, m, &mut r);
            assert!(g.is_connected(), "n={n} m={m}");
            // (m+1)-clique + m edges per later node
            let expect = m * (m + 1) / 2 + (n - m - 1) * m;
            assert_eq!(g.edges().len(), expect, "n={n} m={m}");
            // the seed clique tends to become the hub set
            let max_deg = (0..n).map(|u| g.degree(u)).max().unwrap();
            assert!(max_deg > m, "hubs should exceed the attachment degree");
        }
    }

    #[test]
    fn directed_ring_and_torus_are_strongly_connected() {
        let g = Graph::directed_ring(8);
        assert!(g.is_directed());
        assert_eq!(g.regular_degree(), Some(1)); // out-degree
        assert!(g.is_connected());
        let t = Graph::directed_torus(16);
        assert_eq!(t.regular_degree(), Some(2));
        assert!(t.is_connected());
    }

    #[test]
    fn directed_one_way_chain_is_not_strongly_connected() {
        // 0 → 1 → 2 has no path back to 0
        let g = Graph::from_arcs(3, vec![(0, 1), (1, 2)]);
        assert!(!g.is_connected());
    }

    #[test]
    fn directed_sample_neighbor_follows_arcs() {
        let g = Graph::directed_ring(6);
        let mut r = rng();
        for u in 0..6 {
            assert_eq!(g.sample_neighbor(u, &mut r), (u + 1) % 6);
        }
    }

    #[test]
    #[should_panic]
    fn sample_edge_rejects_directed() {
        let g = Graph::directed_ring(4);
        g.sample_edge(&mut rng());
    }

    #[test]
    fn expander_parses_validates_and_clears_the_gap_bound() {
        assert_eq!(Topology::parse("expander").unwrap(), Topology::Expander(8));
        assert_eq!(Topology::parse("expander6").unwrap(), Topology::Expander(6));
        assert!(Topology::parse("expanderx").is_err());
        assert!(Topology::Expander(8).validate(64).is_ok());
        assert!(Topology::Expander(2).validate(64).is_err()); // r < 3
        assert!(Topology::Expander(64).validate(64).is_err()); // r >= n
        assert!(Topology::Expander(3).validate(9).is_err()); // n*r odd
        let e = Topology::Expander(3).validate(9).unwrap_err();
        assert!(e.contains("even"), "{e}");

        // the certificate actually holds on a checked-size sample
        let mut r = rng();
        let g = Graph::expander(64, 8, &mut r);
        assert_eq!(g.regular_degree(), Some(8));
        assert!(g.is_connected());
        let bound = Graph::expander_gap_bound(8);
        assert!(bound > 2.0 && bound < 3.0, "bound={bound}");
        assert!(g.lambda2() >= bound, "gap {} < bound {bound}", g.lambda2());
    }

    #[test]
    fn validate_matches_constructor_feasibility() {
        assert!(Topology::Torus.validate(16).is_ok());
        assert!(Topology::Torus.validate(10).is_err());
        assert!(Topology::Hypercube.validate(16).is_ok());
        assert!(Topology::Hypercube.validate(12).is_err());
        assert!(Topology::RandomRegular(3).validate(10).is_ok());
        assert!(Topology::RandomRegular(3).validate(9).is_err()); // n*r odd
        assert!(Topology::RandomRegular(12).validate(10).is_err()); // r >= n
        assert!(Topology::Ring.validate(2).is_err());
        assert!(Topology::PowerLaw(2).validate(3).is_err());
        assert!(Topology::PowerLaw(2).validate(16).is_ok());
        // error text names the fix, not just the failure
        let e = Topology::Torus.validate(10).unwrap_err();
        assert!(e.contains("square"), "{e}");
    }

    #[test]
    fn sample_edge_covers_graph() {
        let g = Graph::ring(6);
        let mut r = rng();
        let mut hit = std::collections::HashSet::new();
        for _ in 0..1000 {
            hit.insert(g.sample_edge(&mut r));
        }
        assert_eq!(hit.len(), 6);
    }

    #[test]
    fn matching_is_valid() {
        let g = Graph::complete(12);
        let mut r = rng();
        for _ in 0..50 {
            let m = g.random_matching(&mut r);
            let mut used = std::collections::HashSet::new();
            for (u, v) in &m {
                assert!(used.insert(*u));
                assert!(used.insert(*v));
            }
            // complete graph: greedy always achieves a perfect matching
            assert_eq!(m.len(), 6);
        }
    }

    #[test]
    #[should_panic]
    fn torus_rejects_non_square() {
        Graph::torus(10);
    }
}
