//! Communication topologies — the `r`-regular interaction graphs of §2.
//!
//! The paper's model samples an edge of a connected `r`-regular graph `G`
//! uniformly at random per step; the convergence bounds depend on `r` and on
//! `λ₂`, the second-smallest eigenvalue of the Laplacian (spectral gap).
//! This module builds the standard topologies (complete, ring, 2-D torus,
//! hypercube, random regular) and computes `λ₂` exactly with a dense Jacobi
//! eigensolver (`spectral.rs`) — no external linear-algebra crates.

mod graph;
mod spectral;

pub use graph::{Graph, Topology};
pub use spectral::{jacobi_eigenvalues, laplacian, spectral_gap};
