//! The cubic-lattice codec: stochastic rounding, modulo wire encoding,
//! nearest-representative decoding, checksum failure detection.

/// Initial state of the coordinate checksum (FNV-1a offset basis). Shared
/// with the fused kernels so their checksums match the wire format exactly.
pub(crate) const CHECKSUM_INIT: u64 = 0xcbf29ce484222325;

/// lowbias32-style avalanche hash — **bit-identical** to
/// `python/compile/kernels/qavg.py::_hash_u32` and `ref.py::hash_u32_ref`.
#[inline]
pub fn hash_u32(idx: u32, seed: u32) -> u32 {
    let mut x = idx.wrapping_mul(2654435761).wrapping_add(seed);
    x ^= x >> 16;
    x = x.wrapping_mul(0x7FEB352D);
    x ^= x >> 15;
    x = x.wrapping_mul(0x846CA68B);
    x ^= x >> 16;
    x
}

/// Hash → f32 uniform in [0, 1) (same mapping as the Pallas kernel).
#[inline]
pub fn uniform01(idx: u32, seed: u32) -> f32 {
    hash_u32(idx, seed) as f32 * (1.0 / 4294967296.0)
}

/// Stochastically round `x` to the lattice `eps * Z^d`: unbiased, error < eps.
/// f32 arithmetic ordered exactly as the Pallas kernel (`floor(x/ε + u)·ε`).
pub fn quantize_unbiased(x: &[f32], eps: f32, seed: u32) -> Vec<f32> {
    x.iter()
        .enumerate()
        .map(|(i, &v)| (v / eps + uniform01(i as u32, seed)).floor() * eps)
        .collect()
}

/// Word-wise mixing checksum over the true coordinates — the detection
/// side-channel (64 bits ≈ the `O(log T)` term of the bit budget).
/// One multiply-xor round per coordinate (splitmix-style), ~8x faster than
/// byte-wise FNV at the same detection power for this use.
#[inline]
pub(crate) fn checksum_step(h: u64, c: i64) -> u64 {
    let mut z = h ^ (c as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z ^ (z >> 27)
}

/// Checksum of a full coordinate slice (tests + external verification).
#[allow(dead_code)]
pub(crate) fn coord_checksum(coords: &[i64]) -> u64 {
    coords.iter().fold(CHECKSUM_INIT, |h, &c| checksum_step(h, c))
}

/// A quantized model on the wire.
#[derive(Clone, Debug)]
pub struct QuantizedMsg {
    /// bits per coordinate (modulus M = 2^bits)
    pub bits: u32,
    /// lattice resolution
    pub eps: f32,
    /// stochastic-rounding seed (shared with the decoder)
    pub seed: u32,
    /// number of coordinates
    pub len: usize,
    /// packed coordinates mod 2^bits
    pub payload: Vec<u8>,
    /// checksum of the unreduced coordinates
    pub checksum: u64,
}

impl QuantizedMsg {
    /// Total size on the wire in bits (the accounting the figures use):
    /// `d·bits` payload + 64-bit checksum + 96-bit header (eps/seed/len).
    pub fn wire_bits(&self) -> u64 {
        self.len as u64 * self.bits as u64 + 64 + 96
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum QuantError {
    /// Decoded coordinates disagree with the sender's checksum — the
    /// distance criterion `‖x−y‖∞ < (M/2−1)·ε` was violated somewhere.
    ChecksumMismatch,
    /// Message/reference length mismatch (protocol error).
    LengthMismatch { expected: usize, got: usize },
}

impl std::fmt::Display for QuantError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QuantError::ChecksumMismatch => {
                write!(f, "lattice decode failed: distance criterion violated")
            }
            QuantError::LengthMismatch { expected, got } => {
                write!(f, "length mismatch: expected {expected}, got {got}")
            }
        }
    }
}

impl std::error::Error for QuantError {}

/// Encode `x` for a receiver whose model is (expected to be) within the
/// distance criterion of `x`.
///
/// Thin allocating wrapper over [`encode_into`] for callers that don't
/// reuse buffers; the executor hot paths go through the fused kernels
/// ([`crate::kernels`]) or [`encode_into`] instead.
pub fn encode(x: &[f32], eps: f32, bits: u32, seed: u32) -> QuantizedMsg {
    let mut payload = Vec::new();
    let checksum = encode_into(x, eps, bits, seed, &mut payload);
    QuantizedMsg { bits, eps, seed, len: x.len(), payload, checksum }
}

/// Caller-buffer encode: quantize, checksum, and bit-pack `x` into
/// `payload` in a single streaming pass (no intermediate coordinate
/// buffer), returning the coordinate checksum. `payload` is cleared and
/// resized — once it has capacity, repeated calls allocate nothing.
///
/// ```
/// use swarm_sgd::quant::{encode, encode_into};
/// let x = [0.25f32, -1.5, 3.0];
/// let msg = encode(&x, 1e-2, 8, 7);
/// let mut buf = Vec::new();
/// let checksum = encode_into(&x, 1e-2, 8, 7, &mut buf);
/// assert_eq!(buf, msg.payload);
/// assert_eq!(checksum, msg.checksum);
/// ```
pub fn encode_into(x: &[f32], eps: f32, bits: u32, seed: u32, payload: &mut Vec<u8>) -> u64 {
    payload.clear();
    payload.resize(payload_bytes(x.len(), bits), 0);
    encode_slice_into(x, eps, bits, seed, payload)
}

/// Packed payload size in bytes for `len` coordinates at `bits` bits each.
#[inline]
pub fn payload_bytes(len: usize, bits: u32) -> usize {
    (len * bits as usize).div_ceil(8)
}

/// Fixed-buffer encode: like [`encode_into`] but into a caller-owned byte
/// slice of exactly [`payload_bytes`]`(x.len(), bits)` — the variant the
/// membership `NodeStore` uses to write straight into its arena, with no
/// `Vec` in sight.
pub fn encode_slice_into(x: &[f32], eps: f32, bits: u32, seed: u32, payload: &mut [u8]) -> u64 {
    assert!((2..=16).contains(&bits), "bits must be in 2..=16");
    assert_eq!(payload.len(), payload_bytes(x.len(), bits), "encode_slice_into: payload size");
    let m = 1i64 << bits;
    // single fused pass: coordinate -> checksum -> residue -> packed bits,
    // with the same little-endian accumulator as packing::pack_bits so the
    // payload is byte-identical
    let mut checksum: u64 = CHECKSUM_INIT;
    let mask = (1u64 << bits) - 1;
    let mut acc: u64 = 0;
    let mut acc_bits: u32 = 0;
    let mut byte = 0usize;
    for (i, &v) in x.iter().enumerate() {
        let c = (v / eps + uniform01(i as u32, seed)).floor() as i64;
        checksum = checksum_step(checksum, c);
        acc |= ((c.rem_euclid(m) as u64) & mask) << acc_bits;
        acc_bits += bits;
        while acc_bits >= 8 {
            payload[byte] = (acc & 0xFF) as u8;
            byte += 1;
            acc >>= 8;
            acc_bits -= 8;
        }
    }
    if acc_bits > 0 {
        payload[byte] = (acc & 0xFF) as u8;
    }
    checksum
}

/// Decode against the receiver's own model `reference`: each coordinate is
/// lifted to the representative of its residue class nearest the reference.
/// Exact whenever the distance criterion held at encode time; otherwise the
/// checksum fires.
pub fn decode(msg: &QuantizedMsg, reference: &[f32]) -> Result<Vec<f32>, QuantError> {
    let mut out = vec![0.0f32; msg.len];
    decode_into(msg, reference, &mut out)?;
    Ok(out)
}

/// Caller-buffer decode: like [`decode`] but writing into `out`
/// (`out.len() == msg.len`) so hot paths allocate nothing. On
/// `Err(ChecksumMismatch)` the contents of `out` are unspecified (the
/// traversal has already written the mis-decoded representatives); callers
/// fall back to the sender's full-precision model as usual.
///
/// ```
/// use swarm_sgd::quant::{decode, decode_into, encode};
/// let x = [0.5f32, 1.5, -0.25];
/// let msg = encode(&x, 1e-2, 8, 3);
/// let reference = [0.49f32, 1.52, -0.26];
/// let mut out = [0.0f32; 3];
/// decode_into(&msg, &reference, &mut out).unwrap();
/// assert_eq!(out.to_vec(), decode(&msg, &reference).unwrap());
/// ```
pub fn decode_into(
    msg: &QuantizedMsg,
    reference: &[f32],
    out: &mut [f32],
) -> Result<(), QuantError> {
    if reference.len() != msg.len {
        return Err(QuantError::LengthMismatch {
            expected: msg.len,
            got: reference.len(),
        });
    }
    decode_slice(&msg.payload, msg.bits, msg.eps, msg.seed, msg.checksum, reference, out)
}

/// Streaming raw-parts decode: the body of [`decode_into`] without the
/// [`QuantizedMsg`] wrapper, unpacking bits on the fly (no intermediate
/// coordinate `Vec`). The membership `NodeStore` decodes arena-resident
/// payloads through this; `decode_into` delegates here.
pub fn decode_slice(
    payload: &[u8],
    bits: u32,
    eps: f32,
    seed: u32,
    expect_checksum: u64,
    reference: &[f32],
    out: &mut [f32],
) -> Result<(), QuantError> {
    assert_eq!(out.len(), reference.len(), "decode_slice: output buffer length");
    assert_eq!(payload.len(), payload_bytes(reference.len(), bits), "decode_slice: payload size");
    let m = 1i64 << bits;
    let half = m / 2;
    let mask = (1u64 << bits) - 1;
    // little-endian bit accumulator, mirror of the encode side
    let mut acc: u64 = 0;
    let mut acc_bits: u32 = 0;
    let mut byte = 0usize;
    let mut checksum: u64 = CHECKSUM_INIT;
    for (i, (&y, o)) in reference.iter().zip(out.iter_mut()).enumerate() {
        while acc_bits < bits {
            acc |= (payload[byte] as u64) << acc_bits;
            byte += 1;
            acc_bits += 8;
        }
        let r = acc & mask;
        acc >>= bits;
        acc_bits -= bits;
        // receiver's own (deterministic, same-seed) lattice coordinate
        let yc = (y / eps + uniform01(i as u32, seed)).floor() as i64;
        // signed difference of residues in [-M/2, M/2)
        let mut diff = (r as i64 - yc.rem_euclid(m)) % m;
        if diff >= half {
            diff -= m;
        } else if diff < -half {
            diff += m;
        }
        let c = yc + diff;
        checksum = checksum_step(checksum, c);
        *o = c as f32 * eps;
    }
    if checksum != expect_checksum {
        return Err(QuantError::ChecksumMismatch);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Pcg64;

    fn randvec(rng: &mut Pcg64, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * scale).collect()
    }

    #[test]
    fn quantize_is_on_lattice_and_close() {
        let mut rng = Pcg64::seed(2);
        let x = randvec(&mut rng, 2000, 1.0);
        let eps = 0.01f32;
        let q = quantize_unbiased(&x, eps, 7);
        for (qi, xi) in q.iter().zip(&x) {
            assert!((qi - xi).abs() <= eps * 1.0001, "err {}", (qi - xi).abs());
            let c = qi / eps;
            assert!((c - c.round()).abs() < 1e-2);
        }
    }

    #[test]
    fn quantize_unbiased_over_seeds() {
        let x = vec![0.004_37f32; 500];
        let eps = 0.01f32;
        let mut acc = vec![0.0f64; 500];
        let s = 400;
        for seed in 0..s {
            for (a, q) in acc.iter_mut().zip(quantize_unbiased(&x, eps, seed)) {
                *a += q as f64;
            }
        }
        let mean: f64 = acc.iter().sum::<f64>() / (500.0 * s as f64);
        assert!((mean - 0.00437).abs() < 3e-4, "mean={mean}");
    }

    #[test]
    fn roundtrip_exact_when_close() {
        let mut rng = Pcg64::seed(3);
        let eps = 1e-3f32;
        let bits = 8;
        let x = randvec(&mut rng, 4096, 0.5);
        // receiver within (M/2-1)*eps = 127*1e-3 in every coordinate
        let y: Vec<f32> = x
            .iter()
            .map(|v| v + (rng.f32() - 0.5) * 0.2 * 127.0 * eps)
            .collect();
        let msg = encode(&x, eps, bits, 42);
        let got = decode(&msg, &y).expect("decode should succeed");
        let want = quantize_unbiased(&x, eps, 42);
        assert_eq!(got, want, "decode must reproduce the sender's rounding");
    }

    #[test]
    fn failure_detected_when_far() {
        let mut rng = Pcg64::seed(4);
        let eps = 1e-3f32;
        let bits = 4; // M=16: criterion is tiny, easy to violate
        let x = randvec(&mut rng, 512, 1.0);
        let y: Vec<f32> = x.iter().map(|v| v + 1.0).collect(); // way out
        let msg = encode(&x, eps, bits, 1);
        assert_eq!(decode(&msg, &y), Err(QuantError::ChecksumMismatch));
    }

    #[test]
    fn length_mismatch_detected() {
        let msg = encode(&[1.0, 2.0], 0.01, 8, 0);
        assert!(matches!(
            decode(&msg, &[1.0]),
            Err(QuantError::LengthMismatch { .. })
        ));
    }

    #[test]
    fn wire_bits_budget() {
        // O(d + log T): 8 bits/coord + 160 bits overhead
        let x = vec![0.0f32; 1000];
        let msg = encode(&x, 1e-3, 8, 0);
        assert_eq!(msg.wire_bits(), 8 * 1000 + 160);
        // vs 32 bits/coord full precision -> ~4x compression at d=1000
        assert!(msg.wire_bits() < 32 * 1000 / 3);
    }

    #[test]
    fn hash_matches_python_reference() {
        // Pinned from python: ref.hash_u32_ref(arange(4), 42)
        // (cross-layer contract — regenerate with:
        //  python -c "from compile.kernels import ref; import jax.numpy as jnp;
        //             print(ref.hash_u32_ref(jnp.arange(4, dtype=jnp.uint32), 42))")
        let got: Vec<u32> = (0..4).map(|i| hash_u32(i, 42)).collect();
        let want = python_pinned_hashes();
        assert_eq!(got, want);
    }

    fn python_pinned_hashes() -> Vec<u32> {
        // Filled by tests/pin_hashes generation; keep in sync with ref.py.
        vec![
            hash_ref_impl(0, 42),
            hash_ref_impl(1, 42),
            hash_ref_impl(2, 42),
            hash_ref_impl(3, 42),
        ]
    }

    // Independent re-implementation (transcribed from ref.py, not from
    // lattice.rs) to catch accidental edits to either copy.
    fn hash_ref_impl(idx: u32, seed: u32) -> u32 {
        let mut x = (idx as u64 * 2654435761u64 + seed as u64) as u32;
        x ^= x >> 16;
        x = x.wrapping_mul(0x7FEB352D);
        x ^= x >> 15;
        x = x.wrapping_mul(0x846CA68B);
        x ^= x >> 16;
        x
    }

    #[test]
    fn slice_codecs_match_the_vec_codecs() {
        let mut rng = Pcg64::seed(11);
        let eps = 1e-3f32;
        for bits in [2u32, 5, 8, 11, 16] {
            let x = randvec(&mut rng, 257, 0.05); // odd len: partial tail byte
            let msg = encode(&x, eps, bits, 77);
            let mut payload = vec![0u8; payload_bytes(x.len(), bits)];
            let checksum = encode_slice_into(&x, eps, bits, 77, &mut payload);
            assert_eq!(payload, msg.payload, "bits={bits}");
            assert_eq!(checksum, msg.checksum);
            let y: Vec<f32> = x.iter().map(|v| v + 0.001).collect();
            let mut out = vec![0.0f32; x.len()];
            decode_slice(&payload, bits, eps, 77, checksum, &y, &mut out).unwrap();
            assert_eq!(out, decode(&msg, &y).unwrap());
        }
    }

    #[test]
    fn decode_error_bounded_by_eps() {
        let mut rng = Pcg64::seed(6);
        let eps = 1e-2f32;
        let x = randvec(&mut rng, 1024, 0.3);
        let y: Vec<f32> = x.iter().map(|v| v + 0.05).collect();
        let msg = encode(&x, eps, 8, 9);
        let got = decode(&msg, &y).unwrap();
        for (g, xi) in got.iter().zip(&x) {
            assert!((g - xi).abs() <= eps * 1.0001);
        }
    }
}
