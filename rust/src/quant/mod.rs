//! Lattice/modulo quantization — paper Appendix G, after Davies et al. [12].
//!
//! The property SwarmSGD needs (and that norm-based schemes like QSGD lack)
//! is that the quantization error is bounded by the **distance between** the
//! two endpoints' models, not by the models' norms: the sender transmits its
//! model's cubic-lattice coordinates *modulo M*, and the receiver decodes
//! each coordinate to the representative **nearest its own model**. Whenever
//! `‖x − y‖∞ < (M/2 − 1)·ε` (the distance criterion) decoding is exact, the
//! estimate is unbiased (stochastic rounding), per-coordinate error ≤ ε, and
//! the wire cost is `d·log₂M + O(log T)` bits — the paper's `O(d + log T)`.
//! Failures are *detected* via a 64-bit checksum of the true lattice
//! coordinates (the `log T` part of the budget) and surfaced as
//! [`QuantError::ChecksumMismatch`]; the coordinator then falls back to a
//! full-precision exchange, mirroring the probabilistic failure handling in
//! Theorem G.2.
//!
//! The stochastic-rounding hash is bit-identical to the Pallas kernel
//! (`python/compile/kernels/qavg.py`) and its jnp oracle — cross-layer tests
//! pin this.

mod lattice;
mod packing;
mod qsgd;

pub use lattice::{
    decode, decode_into, decode_slice, encode, encode_into, encode_slice_into,
    hash_u32, payload_bytes, quantize_unbiased, uniform01, QuantError,
    QuantizedMsg,
};
pub use packing::{pack_bits, pack_bits_into, unpack_bits, unpack_bits_into};
pub use qsgd::{
    qsgd_decode, qsgd_decode_into, qsgd_encode, qsgd_encode_into, QsgdMsg,
};

pub(crate) use lattice::{checksum_step, CHECKSUM_INIT};
