//! QSGD-style norm-based stochastic quantization (Alistarh et al. [3]) —
//! implemented as the *counterpoint* to the lattice codec.
//!
//! The paper's §4 Extension 3 argues that norm-based schemes are unsuitable
//! for decentralized **model** exchange: their error scales with ‖x‖₂, and
//! models are far from the origin, so the quantization error would swamp the
//! Γ_t potential. This module exists to make that argument *measurable*
//! (see the ablation test below and `quant_ablation` in the benches): on
//! gradient-like inputs (small norm) QSGD is fine; on model-like inputs
//! (‖x‖ ≫ inter-model distance) its error is orders of magnitude larger
//! than the lattice codec's at the same bit budget.
//!
//! Scheme: x → (‖x‖₂, sign(x_i), ξ_i) with ξ_i stochastic on s levels:
//! ξ encodes |x_i|/‖x‖ rounded to a uniform grid of s = 2^(bits−1) levels.

use crate::rngx::Pcg64;

/// A QSGD-quantized vector on the wire.
#[derive(Clone, Debug)]
pub struct QsgdMsg {
    pub norm: f32,
    /// per-coordinate sign+level packed values (bits wide each)
    pub levels: Vec<u32>,
    pub bits: u32,
    pub len: usize,
}

impl QsgdMsg {
    /// Wire bits: d·bits + 32-bit norm (dense encoding; QSGD's Elias coding
    /// would shave more at low s, irrelevant for the comparison here).
    pub fn wire_bits(&self) -> u64 {
        self.len as u64 * self.bits as u64 + 32
    }
}

/// Quantize with `bits` per coordinate (1 sign bit + level bits).
///
/// Thin allocating wrapper over [`qsgd_encode_into`].
pub fn qsgd_encode(x: &[f32], bits: u32, rng: &mut Pcg64) -> QsgdMsg {
    let mut levels = Vec::new();
    let norm = qsgd_encode_into(x, bits, rng, &mut levels);
    QsgdMsg { norm, levels, bits, len: x.len() }
}

/// Caller-buffer [`qsgd_encode`]: writes the packed sign+level values into
/// `levels` (cleared first, so reuse allocates nothing once capacity
/// exists) and returns the L2 norm.
pub fn qsgd_encode_into(x: &[f32], bits: u32, rng: &mut Pcg64, levels: &mut Vec<u32>) -> f32 {
    assert!((2..=16).contains(&bits));
    let s = (1u32 << (bits - 1)) - 1; // levels
    let norm = x.iter().map(|v| v * v).sum::<f32>().sqrt();
    levels.clear();
    levels.reserve(x.len());
    for &v in x {
        if norm == 0.0 {
            levels.push(0u32);
            continue;
        }
        let r = v.abs() / norm * s as f32;
        let lo = r.floor();
        let level = lo as u32 + u32::from(rng.f32() < (r - lo));
        let sign = u32::from(v < 0.0);
        levels.push((level << 1) | sign);
    }
    norm
}

/// Dequantize.
///
/// Thin allocating wrapper over [`qsgd_decode_into`].
pub fn qsgd_decode(msg: &QsgdMsg) -> Vec<f32> {
    let mut out = vec![0.0f32; msg.levels.len()];
    qsgd_decode_into(msg, &mut out);
    out
}

/// Caller-buffer [`qsgd_decode`]: writes into `out`
/// (`out.len() == msg.levels.len()`).
pub fn qsgd_decode_into(msg: &QsgdMsg, out: &mut [f32]) {
    assert_eq!(out.len(), msg.levels.len(), "qsgd_decode_into: buffer length");
    let s = (1u32 << (msg.bits - 1)) - 1;
    for (o, &lv) in out.iter_mut().zip(&msg.levels) {
        let sign = if lv & 1 == 1 { -1.0f32 } else { 1.0 };
        let level = (lv >> 1) as f32;
        *o = sign * msg.norm * level / s.max(1) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{decode, encode};

    fn rms(a: &[f32], b: &[f32]) -> f64 {
        (a.iter()
            .zip(b)
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            / a.len() as f64)
            .sqrt()
    }

    #[test]
    fn qsgd_unbiased_on_gradients() {
        let mut rng = Pcg64::seed(1);
        let g: Vec<f32> = (0..500).map(|_| rng.normal() as f32 * 0.01).collect();
        let mut acc = vec![0.0f64; 500];
        let trials = 600;
        for _ in 0..trials {
            let m = qsgd_encode(&g, 4, &mut rng);
            for (a, v) in acc.iter_mut().zip(qsgd_decode(&m)) {
                *a += v as f64;
            }
        }
        let mut max_bias = 0.0f64;
        for (a, &gi) in acc.iter().zip(&g) {
            max_bias = max_bias.max((a / trials as f64 - gi as f64).abs());
        }
        // bias ≪ coordinate scale
        assert!(max_bias < 5e-3, "max bias {max_bias}");
    }

    #[test]
    fn qsgd_error_scales_with_norm_lattice_does_not() {
        // THE paper argument (§4 Ext. 3), made quantitative: same 8-bit
        // budget, inputs = two nearby models far from the origin.
        let mut rng = Pcg64::seed(2);
        let d = 4096;
        let offset = 25.0f32; // models live far from 0 (pretrained weights)
        let x: Vec<f32> = (0..d).map(|_| offset + rng.normal() as f32 * 0.01).collect();
        let y: Vec<f32> = x.iter().map(|v| v + 0.005 * rng.normal() as f32).collect();

        // QSGD at 8 bits
        let q = qsgd_encode(&x, 8, &mut rng);
        let qsgd_err = rms(&qsgd_decode(&q), &x);

        // lattice at 8 bits (receiver reference y, eps covering the spread)
        let msg = encode(&x, 1e-3, 8, 7);
        let lat = decode(&msg, &y).expect("distance criterion holds");
        let lattice_err = rms(&lat, &x);

        assert!(
            qsgd_err > 50.0 * lattice_err,
            "QSGD rms {qsgd_err} should dwarf lattice rms {lattice_err} on \
             far-from-origin models"
        );
        // sanity: QSGD error indeed tracks the norm scale
        assert!(qsgd_err > 0.01, "qsgd err {qsgd_err}");
        assert!(lattice_err <= 1e-3, "lattice err {lattice_err}");
    }

    #[test]
    fn qsgd_wire_accounting() {
        let m = qsgd_encode(&vec![1.0; 1000], 8, &mut Pcg64::seed(3));
        assert_eq!(m.wire_bits(), 8 * 1000 + 32);
    }

    #[test]
    fn qsgd_zero_vector() {
        let m = qsgd_encode(&[0.0, 0.0, 0.0], 4, &mut Pcg64::seed(4));
        assert_eq!(qsgd_decode(&m), vec![0.0, 0.0, 0.0]);
    }
}
