//! Dense bit packing for lattice coordinates (1..=16 bits per value).

/// Pack the low `bits` of each value into a dense little-endian bit stream.
///
/// Thin allocating wrapper over [`pack_bits_into`].
pub fn pack_bits(values: &[u32], bits: u32) -> Vec<u8> {
    let mut out = Vec::new();
    pack_bits_into(values, bits, &mut out);
    out
}

/// Caller-buffer [`pack_bits`]: `out` is cleared and resized to the packed
/// length — once it has capacity, repeated calls allocate nothing.
///
/// ```
/// use swarm_sgd::quant::{pack_bits, pack_bits_into};
/// let vals = [3u32, 1, 2];
/// let mut buf = Vec::new();
/// pack_bits_into(&vals, 2, &mut buf);
/// assert_eq!(buf, pack_bits(&vals, 2));
/// ```
pub fn pack_bits_into(values: &[u32], bits: u32, out: &mut Vec<u8>) {
    assert!((1..=16).contains(&bits), "bits must be in 1..=16");
    let total_bits = values.len() * bits as usize;
    out.clear();
    out.resize(total_bits.div_ceil(8), 0);
    let mask = (1u64 << bits) - 1;
    let mut acc: u64 = 0;
    let mut acc_bits: u32 = 0;
    let mut byte = 0usize;
    for &v in values {
        acc |= ((v as u64) & mask) << acc_bits;
        acc_bits += bits;
        while acc_bits >= 8 {
            out[byte] = (acc & 0xFF) as u8;
            byte += 1;
            acc >>= 8;
            acc_bits -= 8;
        }
    }
    if acc_bits > 0 {
        out[byte] = (acc & 0xFF) as u8;
    }
}

/// Inverse of [`pack_bits`]; `count` values of width `bits`.
///
/// Thin allocating wrapper over [`unpack_bits_into`].
pub fn unpack_bits(bytes: &[u8], bits: u32, count: usize) -> Vec<u32> {
    let mut out = Vec::new();
    unpack_bits_into(bytes, bits, count, &mut out);
    out
}

/// Caller-buffer [`unpack_bits`]: `out` is cleared then filled with `count`
/// values — once it has capacity, repeated calls allocate nothing.
pub fn unpack_bits_into(bytes: &[u8], bits: u32, count: usize, out: &mut Vec<u32>) {
    assert!((1..=16).contains(&bits));
    out.clear();
    out.reserve(count);
    let mask = (1u64 << bits) - 1;
    let mut acc: u64 = 0;
    let mut acc_bits: u32 = 0;
    let mut byte = 0usize;
    for _ in 0..count {
        while acc_bits < bits {
            let b = bytes.get(byte).copied().unwrap_or(0);
            acc |= (b as u64) << acc_bits;
            acc_bits += 8;
            byte += 1;
        }
        out.push((acc & mask) as u32);
        acc >>= bits;
        acc_bits -= bits;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngx::Pcg64;

    #[test]
    fn roundtrip_all_widths() {
        let mut rng = Pcg64::seed(1);
        for bits in 1..=16u32 {
            let mask = (1u32 << bits) - 1;
            let vals: Vec<u32> =
                (0..257).map(|_| rng.next_u32() & mask).collect();
            let packed = pack_bits(&vals, bits);
            assert_eq!(packed.len(), (257 * bits as usize).div_ceil(8));
            let got = unpack_bits(&packed, bits, vals.len());
            assert_eq!(got, vals, "bits={bits}");
        }
    }

    #[test]
    fn into_variants_match_wrappers_with_reused_buffers() {
        let mut rng = Pcg64::seed(9);
        let mut packed = Vec::new();
        let mut vals_out = Vec::new();
        for bits in 1..=16u32 {
            let mask = (1u32 << bits) - 1;
            let vals: Vec<u32> = (0..119).map(|_| rng.next_u32() & mask).collect();
            pack_bits_into(&vals, bits, &mut packed);
            assert_eq!(packed, pack_bits(&vals, bits), "bits={bits}");
            unpack_bits_into(&packed, bits, vals.len(), &mut vals_out);
            assert_eq!(vals_out, vals, "bits={bits}");
        }
    }

    #[test]
    fn empty_roundtrip() {
        assert!(pack_bits(&[], 8).is_empty());
        assert!(unpack_bits(&[], 8, 0).is_empty());
    }

    #[test]
    fn eight_bit_is_bytes() {
        let vals = vec![1u32, 2, 250, 255];
        assert_eq!(pack_bits(&vals, 8), vec![1u8, 2, 250, 255]);
    }

    #[test]
    fn high_bits_masked() {
        let vals = vec![0xFFFF_FFFFu32; 3];
        let got = unpack_bits(&pack_bits(&vals, 4), 4, 3);
        assert_eq!(got, vec![0xF, 0xF, 0xF]);
    }
}
