//! Stub [`XlaBackend`] for builds without the `pjrt` feature: keeps the API
//! surface (`load` + the unified [`Backend`] trait) so callers compile
//! unchanged, but loading always fails with an actionable error instead of
//! requiring PJRT headers and libraries at link time.

use super::XlaBackendConfig;
use crate::backend::{Backend, EvalResult};
use crate::rngx::Pcg64;
use std::convert::Infallible;
use std::path::Path;

/// Error returned by [`XlaBackend::load`] when the crate was built without
/// the `pjrt` feature.
#[derive(Debug)]
pub struct PjrtUnavailable {
    preset: String,
}

impl std::fmt::Display for PjrtUnavailable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "preset '{}' needs the XLA/PJRT runtime, but this binary was built \
             without the `pjrt` feature (use an oracle:* preset, or rebuild \
             with `--features pjrt` on a host with xla_extension installed)",
            self.preset
        )
    }
}

impl std::error::Error for PjrtUnavailable {}

/// Uninhabited placeholder for the PJRT-backed training backend. It can
/// never be constructed; the [`Backend`] impl exists purely so
/// `Box<dyn Backend>` call sites compile without the feature.
pub struct XlaBackend {
    never: Infallible,
}

impl XlaBackend {
    /// Always fails: artifact execution requires `--features pjrt`.
    pub fn load(
        _artifacts_dir: &Path,
        name: &str,
        _cfg: XlaBackendConfig,
    ) -> Result<Self, PjrtUnavailable> {
        Err(PjrtUnavailable { preset: name.to_string() })
    }
}

impl Backend for XlaBackend {
    fn dim(&self) -> usize {
        match self.never {}
    }

    fn init(&self) -> (Vec<f32>, Vec<f32>) {
        match self.never {}
    }

    fn step(
        &self,
        _agent: usize,
        _params: &mut [f32],
        _mom: &mut [f32],
        _lr: f32,
        _rng: &mut Pcg64,
    ) -> f64 {
        match self.never {}
    }

    fn eval(&self, _params: &[f32]) -> EvalResult {
        match self.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_load_fails_with_actionable_error() {
        let err = XlaBackend::load(Path::new("artifacts"), "mlp_s", XlaBackendConfig::default())
            .err()
            .expect("stub must never load");
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "{msg}");
        assert!(msg.contains("mlp_s"), "{msg}");
    }
}
