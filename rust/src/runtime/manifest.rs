//! Artifact manifest: the INI file `aot.py` writes next to the HLO text.

use crate::config::{parse_ini, DataKind};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Metadata for one lowered model preset.
#[derive(Clone, Debug)]
pub struct ModelManifest {
    /// preset name, e.g. "mlp_s"
    pub name: String,
    /// model family: mlp | cnn | transformer
    pub model: String,
    pub param_count: usize,
    pub batch: usize,
    /// scan length of the step_k fast-path artifact
    pub k: usize,
    /// lattice resolution baked into the qavg artifact
    pub qavg_eps: f32,
    /// modality + shape fields (in_dim/classes, image/chan_in, vocab/seq)
    pub fields: HashMap<String, String>,
    /// artifact paths (absolute), keyed by init/step/step_k/eval/qavg
    pub artifacts: HashMap<String, PathBuf>,
}

impl ModelManifest {
    pub fn kind(&self) -> DataKind {
        match self.fields.get("kind").map(|s| s.as_str()) {
            Some("image") => DataKind::Image,
            Some("tokens") => DataKind::Tokens,
            _ => DataKind::Vector,
        }
    }

    pub fn field_usize(&self, key: &str) -> Option<usize> {
        self.fields.get(key).and_then(|v| v.parse().ok())
    }

    pub fn artifact(&self, which: &str) -> Option<&Path> {
        self.artifacts.get(which).map(|p| p.as_path())
    }
}

/// Load `<dir>/manifest.txt`; returns all presets found.
pub fn load_manifest(dir: &Path) -> Result<Vec<ModelManifest>, String> {
    let path = dir.join("manifest.txt");
    let text = std::fs::read_to_string(&path)
        .map_err(|e| format!("cannot read {} — run `make artifacts` first ({e})", path.display()))?;
    let doc = parse_ini(&text)?;
    let mut out = Vec::new();
    for sec in &doc.sections {
        if sec.name.is_empty() {
            continue;
        }
        let mut artifacts = HashMap::new();
        for which in ["init", "step", "step_k", "eval", "qavg"] {
            if let Some(f) = sec.get(which) {
                artifacts.insert(which.to_string(), dir.join(f));
            }
        }
        let mut fields = HashMap::new();
        for (k, v) in &sec.entries {
            fields.insert(k.clone(), v.clone());
        }
        out.push(ModelManifest {
            name: sec.name.clone(),
            model: sec.require("model")?,
            param_count: sec.require("param_count")?,
            batch: sec.require("batch")?,
            k: sec.require("k")?,
            qavg_eps: sec.parse("qavg_eps").unwrap_or(1e-3),
            fields,
            artifacts,
        });
    }
    if out.is_empty() {
        return Err(format!("{}: no presets found", path.display()));
    }
    Ok(out)
}

/// Find one preset by name.
pub fn find_preset(dir: &Path, name: &str) -> Result<ModelManifest, String> {
    load_manifest(dir)?
        .into_iter()
        .find(|m| m.name == name)
        .ok_or_else(|| format!("preset '{name}' not in {}/manifest.txt (run `make artifacts`)", dir.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tmp(text: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("swarm_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), text).unwrap();
        dir
    }

    #[test]
    fn parses_manifest() {
        let dir = write_tmp(
            "[mlp_s]\nmodel = mlp\nparam_count = 100\nbatch = 32\nk = 4\n\
             qavg_eps = 0.001\nkind = vector\nin_dim = 64\nclasses = 10\n\
             init = mlp_s_init.hlo.txt\nstep = mlp_s_step.hlo.txt\n\
             step_k = mlp_s_step_k.hlo.txt\neval = mlp_s_eval.hlo.txt\nqavg = mlp_s_qavg.hlo.txt\n",
        );
        let ms = load_manifest(&dir).unwrap();
        assert_eq!(ms.len(), 1);
        let m = &ms[0];
        assert_eq!(m.name, "mlp_s");
        assert_eq!(m.param_count, 100);
        assert_eq!(m.kind(), DataKind::Vector);
        assert_eq!(m.field_usize("in_dim"), Some(64));
        assert!(m.artifact("step").unwrap().ends_with("mlp_s_step.hlo.txt"));
        assert!(m.artifact("nonexistent").is_none());
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = load_manifest(Path::new("/definitely/not/here")).unwrap_err();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
