//! Compiled model: the five PJRT executables of one preset + typed wrappers.

use super::manifest::ModelManifest;
use crate::data::Batch;
use anyhow::{anyhow, Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

/// A fully compiled model preset, ready to execute.
pub struct XlaModel {
    pub manifest: ModelManifest,
    client: PjRtClient,
    init_exe: PjRtLoadedExecutable,
    step_exe: PjRtLoadedExecutable,
    step_k_exe: Option<PjRtLoadedExecutable>,
    eval_exe: PjRtLoadedExecutable,
    qavg_exe: Option<PjRtLoadedExecutable>,
}

fn compile(client: &PjRtClient, path: &std::path::Path) -> Result<PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
    )
    .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {}", path.display()))
}

impl XlaModel {
    /// Compile all artifacts of `manifest` on a fresh CPU PJRT client.
    pub fn load(manifest: ModelManifest) -> Result<Self> {
        let client = PjRtClient::cpu()?;
        let get = |which: &str| -> Result<PjRtLoadedExecutable> {
            let p = manifest
                .artifact(which)
                .ok_or_else(|| anyhow!("manifest missing artifact '{which}'"))?;
            compile(&client, p)
        };
        let init_exe = get("init")?;
        let step_exe = get("step")?;
        let eval_exe = get("eval")?;
        let step_k_exe = manifest.artifact("step_k").map(|_| get("step_k")).transpose()?;
        let qavg_exe = manifest.artifact("qavg").map(|_| get("qavg")).transpose()?;
        Ok(Self { manifest, client, init_exe, step_exe, step_k_exe, eval_exe, qavg_exe })
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    pub fn param_count(&self) -> usize {
        self.manifest.param_count
    }

    /// init(seed) -> (params, mom)
    pub fn init(&self, seed: i32) -> Result<(Vec<f32>, Vec<f32>)> {
        let out = self.init_exe.execute::<Literal>(&[Literal::scalar(seed)])?[0][0]
            .to_literal_sync()?;
        let (p, m) = out.to_tuple2()?;
        Ok((p.to_vec::<f32>()?, m.to_vec::<f32>()?))
    }

    fn batch_literals(&self, batch: &Batch, shape_x: &[i64], shape_y: &[i64]) -> Result<(Literal, Literal)> {
        Ok(match batch {
            Batch::Dense { x, y } => (
                Literal::vec1(x).reshape(shape_x)?,
                Literal::vec1(y).reshape(shape_y)?,
            ),
            Batch::Tokens { x, y } => (
                Literal::vec1(x).reshape(shape_x)?,
                Literal::vec1(y).reshape(shape_y)?,
            ),
        })
    }

    /// One train step: (params, mom, batch, lr) -> (params', mom', loss).
    /// `shape_x`/`shape_y` are the batch tensor shapes from the manifest.
    pub fn step(
        &self,
        params: &mut [f32],
        mom: &mut [f32],
        batch: &Batch,
        shape_x: &[i64],
        shape_y: &[i64],
        lr: f32,
    ) -> Result<f64> {
        let pl = Literal::vec1(params);
        let ml = Literal::vec1(mom);
        let (xl, yl) = self.batch_literals(batch, shape_x, shape_y)?;
        let lrl = Literal::scalar(lr);
        let out = self.step_exe.execute(&[&pl, &ml, &xl, &yl, &lrl])?[0][0]
            .to_literal_sync()?;
        let (p2, m2, loss) = out.to_tuple3()?;
        p2.copy_raw_to(params)?;
        m2.copy_raw_to(mom)?;
        Ok(loss.get_first_element::<f32>()? as f64)
    }

    /// K fused steps via the lax.scan artifact: batches stacked on axis 0.
    /// Returns the mean loss across the K microbatches.
    pub fn step_k(
        &self,
        params: &mut [f32],
        mom: &mut [f32],
        batches: &[Batch],
        shape_x: &[i64],
        shape_y: &[i64],
        lr: f32,
    ) -> Result<f64> {
        let exe = self
            .step_k_exe
            .as_ref()
            .ok_or_else(|| anyhow!("preset has no step_k artifact"))?;
        assert_eq!(batches.len(), self.manifest.k, "step_k needs exactly k batches");
        // stack
        let (mut xs_f, mut xs_i, mut ys) = (Vec::new(), Vec::new(), Vec::<i32>::new());
        let mut dense = true;
        for b in batches {
            match b {
                Batch::Dense { x, y } => {
                    xs_f.extend_from_slice(x);
                    ys.extend_from_slice(y);
                }
                Batch::Tokens { x, y } => {
                    dense = false;
                    xs_i.extend_from_slice(x);
                    ys.extend_from_slice(y);
                }
            }
        }
        let k = self.manifest.k as i64;
        let sx: Vec<i64> = std::iter::once(k).chain(shape_x.iter().copied()).collect();
        let sy: Vec<i64> = std::iter::once(k).chain(shape_y.iter().copied()).collect();
        let xl = if dense {
            Literal::vec1(&xs_f).reshape(&sx)?
        } else {
            Literal::vec1(&xs_i).reshape(&sx)?
        };
        let yl = Literal::vec1(&ys).reshape(&sy)?;
        let pl = Literal::vec1(params);
        let ml = Literal::vec1(mom);
        let lrl = Literal::scalar(lr);
        let out = exe.execute(&[&pl, &ml, &xl, &yl, &lrl])?[0][0].to_literal_sync()?;
        let (p2, m2, loss) = out.to_tuple3()?;
        p2.copy_raw_to(params)?;
        m2.copy_raw_to(mom)?;
        Ok(loss.get_first_element::<f32>()? as f64)
    }

    /// eval(params, batch) -> (loss, correct_count)
    pub fn eval(
        &self,
        params: &[f32],
        batch: &Batch,
        shape_x: &[i64],
        shape_y: &[i64],
    ) -> Result<(f64, f64)> {
        let pl = Literal::vec1(params);
        let (xl, yl) = self.batch_literals(batch, shape_x, shape_y)?;
        let out = self.eval_exe.execute(&[&pl, &xl, &yl])?[0][0].to_literal_sync()?;
        let (loss, correct) = out.to_tuple2()?;
        Ok((
            loss.get_first_element::<f32>()? as f64,
            correct.get_first_element::<f32>()? as f64,
        ))
    }

    /// Quantized average via the Pallas lattice kernel artifact:
    /// (x, y, seed) -> (x + Q_eps(y)) / 2.
    pub fn qavg(&self, x: &[f32], y: &[f32], seed: u32) -> Result<Vec<f32>> {
        let exe = self
            .qavg_exe
            .as_ref()
            .ok_or_else(|| anyhow!("preset has no qavg artifact"))?;
        let xl = Literal::vec1(x);
        let yl = Literal::vec1(y);
        let sl = Literal::scalar(seed);
        let out = exe.execute(&[&xl, &yl, &sl])?[0][0].to_literal_sync()?;
        let avg = out.to_tuple1()?;
        Ok(avg.to_vec::<f32>()?)
    }
}
