//! [`XlaBackend`] — the real three-layer training path as a [`TrainBackend`]:
//! per-agent synthetic data shards feed the AOT-compiled JAX+Pallas step
//! executables through PJRT.

use super::manifest::{find_preset, ModelManifest};
use super::model::XlaModel;
use super::XlaBackendConfig;
use crate::backend::{EvalResult, TrainBackend};
use crate::config::{DataKind, ShardMode};
use crate::data::{
    dirichlet_shards, iid_shards, label_shards, Batch, ImageDataset, MarkovCorpus,
    ShardIter, TokenBatcher, VectorDataset,
};
use crate::rngx::Pcg64;
use anyhow::Result;
use std::path::Path;

enum DataSource {
    Dense {
        train: DenseKind,
        shards: Vec<ShardIter>,
    },
    Tokens {
        batchers: Vec<TokenBatcher>,
        /// held-out token stream
        test: Vec<i32>,
    },
}

enum DenseKind {
    Vector(VectorDataset),
    Image(ImageDataset),
}

impl DenseKind {
    fn batch(&self, idxs: &[usize]) -> Batch {
        match self {
            DenseKind::Vector(d) => d.batch(idxs),
            DenseKind::Image(d) => d.batch(idxs),
        }
    }
}

/// The PJRT-backed training backend.
pub struct XlaBackend {
    pub model: XlaModel,
    cfg: XlaBackendConfig,
    source: DataSource,
    /// held-out dense set (None for token models)
    test_dense: Option<DenseKind>,
    shape_x: Vec<i64>,
    shape_y: Vec<i64>,
    rng: Pcg64,
    /// lazily measured: is the lax.scan step_k artifact faster per step
    /// than k separate dispatches on this host? (XLA CPU often pessimizes
    /// scan bodies — see EXPERIMENTS.md §Perf)
    step_k_faster: std::cell::Cell<Option<bool>>,
}

impl XlaBackend {
    /// Load preset `name` from `artifacts_dir` and synthesize shards.
    pub fn load(artifacts_dir: &Path, name: &str, cfg: XlaBackendConfig) -> Result<Self> {
        let manifest = find_preset(artifacts_dir, name).map_err(anyhow::Error::msg)?;
        let model = XlaModel::load(manifest)?;
        Self::with_model(model, cfg)
    }

    pub fn with_model(model: XlaModel, cfg: XlaBackendConfig) -> Result<Self> {
        let mut rng = Pcg64::seed(cfg.seed);
        let m = &model.manifest;
        let b = m.batch as i64;
        let (source, test_dense, shape_x, shape_y) = match m.kind() {
            DataKind::Vector => {
                let dim = m.field_usize("in_dim").expect("manifest in_dim");
                let classes = m.field_usize("classes").expect("manifest classes");
                let n = cfg.agents * cfg.data_per_agent;
                let (train, test) = VectorDataset::generate_split(
                    n,
                    m.batch * cfg.eval_batches,
                    dim,
                    classes,
                    cfg.separation,
                    &mut rng,
                );
                let shards = make_shards(&train.y, cfg.agents, cfg.shard, &mut rng);
                let iters = shards
                    .into_iter()
                    .map(|s| ShardIter::new(s, rng.split(11)))
                    .collect();
                (
                    DataSource::Dense { train: DenseKind::Vector(train), shards: iters },
                    Some(DenseKind::Vector(test)),
                    vec![b, dim as i64],
                    vec![b],
                )
            }
            DataKind::Image => {
                let hw = m.field_usize("image").expect("manifest image");
                let chans = m.field_usize("chan_in").expect("manifest chan_in");
                let classes = m.field_usize("classes").expect("manifest classes");
                let n = cfg.agents * cfg.data_per_agent;
                let (train, test) = ImageDataset::generate_split(
                    n,
                    m.batch * cfg.eval_batches,
                    hw,
                    chans,
                    classes,
                    cfg.separation,
                    &mut rng,
                );
                let shards = make_shards(&train.y, cfg.agents, cfg.shard, &mut rng);
                let iters = shards
                    .into_iter()
                    .map(|s| ShardIter::new(s, rng.split(13)))
                    .collect();
                (
                    DataSource::Dense { train: DenseKind::Image(train), shards: iters },
                    Some(DenseKind::Image(test)),
                    vec![b, hw as i64, hw as i64, chans as i64],
                    vec![b],
                )
            }
            DataKind::Tokens => {
                let vocab = m.field_usize("vocab").expect("manifest vocab");
                let seq = m.field_usize("seq").expect("manifest seq");
                let total = cfg.agents * cfg.data_per_agent + m.batch * cfg.eval_batches * (seq + 1);
                let corpus = MarkovCorpus::generate(vocab, total, 4, &mut rng);
                let test_len = m.batch * cfg.eval_batches * (seq + 1);
                let (train_toks, test_toks) = corpus.tokens.split_at(corpus.len() - test_len);
                let shard_len = train_toks.len() / cfg.agents;
                let batchers = (0..cfg.agents)
                    .map(|a| {
                        let lo = a * shard_len;
                        TokenBatcher::new(
                            &train_toks[lo..lo + shard_len],
                            seq,
                            m.batch,
                            rng.split(a as u64),
                        )
                    })
                    .collect();
                (
                    DataSource::Tokens { batchers, test: test_toks.to_vec() },
                    None,
                    vec![b, seq as i64],
                    vec![b, seq as i64],
                )
            }
        };
        Ok(Self {
            model,
            cfg,
            source,
            test_dense,
            shape_x,
            shape_y,
            rng,
            step_k_faster: std::cell::Cell::new(None),
        })
    }

    pub fn manifest(&self) -> &ModelManifest {
        &self.model.manifest
    }

    fn next_batch(&mut self, agent: usize) -> Batch {
        match &mut self.source {
            DataSource::Dense { train, shards } => {
                let idxs = shards[agent].next_indices(self.model.manifest.batch);
                train.batch(&idxs)
            }
            DataSource::Tokens { batchers, .. } => batchers[agent].next_batch(),
        }
    }

    /// Evaluation batches over the held-out set (deterministic coverage).
    fn eval_batches(&mut self) -> Vec<Batch> {
        let bsz = self.model.manifest.batch;
        match (&self.test_dense, &self.source) {
            (Some(test), _) => {
                let n = match test {
                    DenseKind::Vector(d) => d.len(),
                    DenseKind::Image(d) => d.len(),
                };
                (0..self.cfg.eval_batches)
                    .map(|k| {
                        let idxs: Vec<usize> =
                            (0..bsz).map(|i| (k * bsz + i) % n).collect();
                        test.batch(&idxs)
                    })
                    .collect()
            }
            (None, DataSource::Tokens { test, .. }) => {
                let seq = self
                    .model
                    .manifest
                    .field_usize("seq")
                    .expect("manifest seq");
                let mut out = Vec::new();
                let mut pos = 0usize;
                for _ in 0..self.cfg.eval_batches {
                    let mut x = Vec::with_capacity(bsz * seq);
                    let mut y = Vec::with_capacity(bsz * seq);
                    for _ in 0..bsz {
                        if pos + seq + 1 >= test.len() {
                            pos = 0;
                        }
                        x.extend_from_slice(&test[pos..pos + seq]);
                        y.extend_from_slice(&test[pos + 1..pos + seq + 1]);
                        pos += seq;
                    }
                    out.push(Batch::Tokens { x, y });
                }
                out
            }
            _ => unreachable!(),
        }
    }

    /// Tokens-per-label-position for accuracy normalization.
    fn labels_per_batch(&self) -> f64 {
        let m = &self.model.manifest;
        match m.kind() {
            DataKind::Tokens => {
                (m.batch * m.field_usize("seq").unwrap_or(1)) as f64
            }
            _ => m.batch as f64,
        }
    }
}

fn make_shards(
    labels: &[i32],
    agents: usize,
    mode: ShardMode,
    rng: &mut Pcg64,
) -> Vec<Vec<usize>> {
    match mode {
        ShardMode::Iid => iid_shards(labels.len(), agents, rng),
        ShardMode::ByLabel => label_shards(labels, agents),
        ShardMode::Dirichlet(a) => dirichlet_shards(labels, agents, a, rng),
    }
}

impl TrainBackend for XlaBackend {
    fn param_count(&self) -> usize {
        self.model.param_count()
    }

    fn init(&mut self, seed: i64) -> (Vec<f32>, Vec<f32>) {
        self.model.init(seed as i32).expect("init artifact failed")
    }

    fn step(&mut self, agent: usize, params: &mut [f32], mom: &mut [f32], lr: f32) -> f64 {
        let batch = self.next_batch(agent);
        let _ = &mut self.rng;
        self.model
            .step(params, mom, &batch, &self.shape_x, &self.shape_y, lr)
            .expect("step artifact failed")
    }

    fn step_burst(&mut self, agent: usize, params: &mut [f32], mom: &mut [f32], lr: f32, h: u64) -> f64 {
        let k = self.model.manifest.k as u64;
        // First time we see a burst that could use the fused lax.scan
        // artifact, race it against k unit dispatches (both do real
        // training work, so nothing is wasted) and remember the winner.
        if self.step_k_faster.get().is_none() && h >= 2 * k && k > 1 {
            let t0 = std::time::Instant::now();
            let batches: Vec<Batch> = (0..k).map(|_| self.next_batch(agent)).collect();
            self.model
                .step_k(params, mom, &batches, &self.shape_x, &self.shape_y, lr)
                .expect("step_k artifact failed");
            let fused = t0.elapsed();
            let t1 = std::time::Instant::now();
            for _ in 0..k {
                self.step(agent, params, mom, lr);
            }
            let unit = t1.elapsed();
            self.step_k_faster.set(Some(fused < unit));
            return self.step_burst(agent, params, mom, lr, h.saturating_sub(2 * k));
        }
        let use_fused = self.step_k_faster.get().unwrap_or(false) && k > 1;
        let mut remaining = h;
        let mut last = f64::NAN;
        if use_fused {
            while remaining >= k {
                let batches: Vec<Batch> =
                    (0..k).map(|_| self.next_batch(agent)).collect();
                last = self
                    .model
                    .step_k(params, mom, &batches, &self.shape_x, &self.shape_y, lr)
                    .expect("step_k artifact failed");
                remaining -= k;
            }
        }
        for _ in 0..remaining {
            last = self.step(agent, params, mom, lr);
        }
        last
    }

    fn eval(&mut self, params: &[f32]) -> EvalResult {
        let batches = self.eval_batches();
        let mut loss = 0.0;
        let mut correct = 0.0;
        let denom = (batches.len() as f64) * self.labels_per_batch();
        for b in &batches {
            let (l, c) = self
                .model
                .eval(params, b, &self.shape_x, &self.shape_y)
                .expect("eval artifact failed");
            loss += l;
            correct += c;
        }
        EvalResult {
            loss: loss / batches.len() as f64,
            accuracy: correct / denom,
        }
    }

    fn epochs(&self, agent: usize) -> f64 {
        match &self.source {
            DataSource::Dense { shards, .. } => shards[agent].epochs(),
            DataSource::Tokens { batchers, .. } => batchers[agent].epochs(),
        }
    }
}
