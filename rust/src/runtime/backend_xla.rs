//! [`XlaBackend`] — the real three-layer training path as a unified
//! [`Backend`]: per-agent synthetic data shards feed the AOT-compiled
//! JAX+Pallas step executables through PJRT.
//!
//! PR 2 adapted it to the `&self + Sync` backend contract:
//!
//! * shard/batch selection is **stateless** — batch indices (dense) and
//!   window offsets (tokens) are drawn from the caller's RNG, so the data
//!   order a node sees is fixed by its private stream, not by thread
//!   interleaving;
//! * PJRT executable dispatch is serialized through an internal lock (the
//!   linked xla_extension client is not known to be thread-safe), so the
//!   parallel executor is *correct* on this backend but gains no XLA-side
//!   speedup yet — the ROADMAP's "thread-safe PJRT backend" item.

use super::manifest::{find_preset, ModelManifest};
use super::model::XlaModel;
use super::XlaBackendConfig;
use crate::backend::{Backend, EvalResult};
use crate::config::{DataKind, ShardMode};
use crate::data::{
    dirichlet_shards, draw_batch_indices, draw_token_batch, iid_shards, label_shards, Batch,
    ImageDataset, MarkovCorpus, VectorDataset,
};
use crate::rngx::Pcg64;
use anyhow::Result;
use std::path::Path;
use std::sync::Mutex;

enum DataSource {
    Dense {
        train: DenseKind,
        /// immutable per-agent example index lists
        shards: Vec<Vec<usize>>,
    },
    Tokens {
        /// immutable per-agent token shards
        shards: Vec<Vec<i32>>,
        seq: usize,
    },
}

enum DenseKind {
    Vector(VectorDataset),
    Image(ImageDataset),
}

impl DenseKind {
    fn batch(&self, idxs: &[usize]) -> Batch {
        match self {
            DenseKind::Vector(d) => d.batch(idxs),
            DenseKind::Image(d) => d.batch(idxs),
        }
    }
}

/// The PJRT-backed training backend.
pub struct XlaBackend {
    model: XlaModel,
    cfg: XlaBackendConfig,
    source: DataSource,
    /// held-out dense set (None for token models)
    test_dense: Option<DenseKind>,
    /// held-out token stream (token models)
    test_tokens: Option<Vec<i32>>,
    shape_x: Vec<i64>,
    shape_y: Vec<i64>,
    /// examples (dense) / windows (tokens) per shard, for `epochs`
    shard_sizes: Vec<f64>,
    /// serializes every PJRT dispatch (client thread-safety unproven)
    dispatch: Mutex<()>,
    /// lazily measured: is the lax.scan step_k artifact faster per step
    /// than k separate dispatches on this host? (XLA CPU often pessimizes
    /// scan bodies — see EXPERIMENTS.md §Perf)
    step_k_faster: Mutex<Option<bool>>,
}

// Safety: all `XlaModel` executions go through `Self::run`, which holds the
// `dispatch` mutex; the remaining fields are plain owned data.
unsafe impl Sync for XlaBackend {}

impl XlaBackend {
    /// Load preset `name` from `artifacts_dir` and synthesize shards.
    pub fn load(artifacts_dir: &Path, name: &str, cfg: XlaBackendConfig) -> Result<Self> {
        let manifest = find_preset(artifacts_dir, name).map_err(anyhow::Error::msg)?;
        let model = XlaModel::load(manifest)?;
        Self::with_model(model, cfg)
    }

    pub fn with_model(model: XlaModel, cfg: XlaBackendConfig) -> Result<Self> {
        let mut rng = Pcg64::seed(cfg.seed);
        let m = &model.manifest;
        let b = m.batch as i64;
        let (source, test_dense, test_tokens, shape_x, shape_y) = match m.kind() {
            DataKind::Vector => {
                let dim = m.field_usize("in_dim").expect("manifest in_dim");
                let classes = m.field_usize("classes").expect("manifest classes");
                let n = cfg.agents * cfg.data_per_agent;
                let (train, test) = VectorDataset::generate_split(
                    n,
                    m.batch * cfg.eval_batches,
                    dim,
                    classes,
                    cfg.separation,
                    &mut rng,
                );
                let shards = make_shards(&train.y, cfg.agents, cfg.shard, &mut rng);
                (
                    DataSource::Dense { train: DenseKind::Vector(train), shards },
                    Some(DenseKind::Vector(test)),
                    None,
                    vec![b, dim as i64],
                    vec![b],
                )
            }
            DataKind::Image => {
                let hw = m.field_usize("image").expect("manifest image");
                let chans = m.field_usize("chan_in").expect("manifest chan_in");
                let classes = m.field_usize("classes").expect("manifest classes");
                let n = cfg.agents * cfg.data_per_agent;
                let (train, test) = ImageDataset::generate_split(
                    n,
                    m.batch * cfg.eval_batches,
                    hw,
                    chans,
                    classes,
                    cfg.separation,
                    &mut rng,
                );
                let shards = make_shards(&train.y, cfg.agents, cfg.shard, &mut rng);
                (
                    DataSource::Dense { train: DenseKind::Image(train), shards },
                    Some(DenseKind::Image(test)),
                    None,
                    vec![b, hw as i64, hw as i64, chans as i64],
                    vec![b],
                )
            }
            DataKind::Tokens => {
                let vocab = m.field_usize("vocab").expect("manifest vocab");
                let seq = m.field_usize("seq").expect("manifest seq");
                let total =
                    cfg.agents * cfg.data_per_agent + m.batch * cfg.eval_batches * (seq + 1);
                let corpus = MarkovCorpus::generate(vocab, total, 4, &mut rng);
                let test_len = m.batch * cfg.eval_batches * (seq + 1);
                let (train_toks, test_toks) = corpus.tokens.split_at(corpus.len() - test_len);
                let shard_len = train_toks.len() / cfg.agents;
                assert!(
                    shard_len > seq + 1,
                    "token shard ({shard_len} tokens) must exceed seq+1 ({}); \
                     raise data_per_agent",
                    seq + 1
                );
                let shards: Vec<Vec<i32>> = (0..cfg.agents)
                    .map(|a| train_toks[a * shard_len..(a + 1) * shard_len].to_vec())
                    .collect();
                (
                    DataSource::Tokens { shards, seq },
                    None,
                    Some(test_toks.to_vec()),
                    vec![b, seq as i64],
                    vec![b, seq as i64],
                )
            }
        };
        let shard_sizes: Vec<f64> = match &source {
            DataSource::Dense { shards, .. } => {
                shards.iter().map(|s| s.len() as f64).collect()
            }
            DataSource::Tokens { shards, seq } => {
                shards.iter().map(|s| (s.len() / seq).max(1) as f64).collect()
            }
        };
        Ok(Self {
            model,
            cfg,
            source,
            test_dense,
            test_tokens,
            shape_x,
            shape_y,
            shard_sizes,
            dispatch: Mutex::new(()),
            step_k_faster: Mutex::new(None),
        })
    }

    pub fn manifest(&self) -> &ModelManifest {
        &self.model.manifest
    }

    /// Run a model dispatch under the serialization lock.
    fn run<R>(&self, f: impl FnOnce(&XlaModel) -> R) -> R {
        let _g = self.dispatch.lock().expect("dispatch lock poisoned");
        f(&self.model)
    }

    /// The fused quantize-average Pallas artifact (benches/tests).
    pub fn qavg(&self, x: &[f32], y: &[f32], seed: u32) -> Result<Vec<f32>> {
        self.run(|m| m.qavg(x, y, seed))
    }

    /// Draw one minibatch for `agent` from the caller's RNG (the shared
    /// `data::draw_*` rules, so all backends consume node streams alike).
    fn next_batch(&self, agent: usize, rng: &mut Pcg64) -> Batch {
        let bsz = self.model.manifest.batch;
        match &self.source {
            DataSource::Dense { train, shards } => {
                train.batch(&draw_batch_indices(&shards[agent], bsz, rng))
            }
            DataSource::Tokens { shards, seq } => {
                draw_token_batch(&shards[agent], *seq, bsz, rng)
            }
        }
    }

    /// Evaluation batches over the held-out set (deterministic coverage).
    fn eval_batches(&self) -> Vec<Batch> {
        let bsz = self.model.manifest.batch;
        match (&self.test_dense, &self.test_tokens) {
            (Some(test), _) => {
                let n = match test {
                    DenseKind::Vector(d) => d.len(),
                    DenseKind::Image(d) => d.len(),
                };
                (0..self.cfg.eval_batches)
                    .map(|k| {
                        let idxs: Vec<usize> = (0..bsz).map(|i| (k * bsz + i) % n).collect();
                        test.batch(&idxs)
                    })
                    .collect()
            }
            (None, Some(test)) => {
                let seq = self.model.manifest.field_usize("seq").expect("manifest seq");
                let mut out = Vec::new();
                let mut pos = 0usize;
                for _ in 0..self.cfg.eval_batches {
                    let mut x = Vec::with_capacity(bsz * seq);
                    let mut y = Vec::with_capacity(bsz * seq);
                    for _ in 0..bsz {
                        if pos + seq + 1 >= test.len() {
                            pos = 0;
                        }
                        x.extend_from_slice(&test[pos..pos + seq]);
                        y.extend_from_slice(&test[pos + 1..pos + seq + 1]);
                        pos += seq;
                    }
                    out.push(Batch::Tokens { x, y });
                }
                out
            }
            _ => unreachable!(),
        }
    }

    /// Tokens-per-label-position for accuracy normalization.
    fn labels_per_batch(&self) -> f64 {
        let m = &self.model.manifest;
        match m.kind() {
            DataKind::Tokens => (m.batch * m.field_usize("seq").unwrap_or(1)) as f64,
            _ => m.batch as f64,
        }
    }
}

fn make_shards(
    labels: &[i32],
    agents: usize,
    mode: ShardMode,
    rng: &mut Pcg64,
) -> Vec<Vec<usize>> {
    match mode {
        ShardMode::Iid => iid_shards(labels.len(), agents, rng),
        ShardMode::ByLabel => label_shards(labels, agents),
        ShardMode::Dirichlet(a) => dirichlet_shards(labels, agents, a, rng),
    }
}

impl Backend for XlaBackend {
    fn dim(&self) -> usize {
        self.model.param_count()
    }

    fn init(&self) -> (Vec<f32>, Vec<f32>) {
        self.run(|m| m.init(self.cfg.seed as i32)).expect("init artifact failed")
    }

    fn step(
        &self,
        agent: usize,
        params: &mut [f32],
        mom: &mut [f32],
        lr: f32,
        rng: &mut Pcg64,
    ) -> f64 {
        let batch = self.next_batch(agent, rng);
        self.run(|m| m.step(params, mom, &batch, &self.shape_x, &self.shape_y, lr))
            .expect("step artifact failed")
    }

    fn step_burst(
        &self,
        agent: usize,
        params: &mut [f32],
        mom: &mut [f32],
        lr: f32,
        h: u64,
        rng: &mut Pcg64,
    ) -> f64 {
        let k = self.model.manifest.k as u64;
        // First time we see a burst that could use the fused lax.scan
        // artifact, race it against k unit dispatches (both do real
        // training work, so nothing is wasted) and remember the winner.
        // The verdict lock is held across the whole measurement so a second
        // worker neither races the decision nor pollutes the timings with
        // dispatch-mutex contention.
        let mut verdict = self.step_k_faster.lock().expect("step_k lock poisoned");
        if verdict.is_none() && h >= 2 * k && k > 1 {
            let t0 = std::time::Instant::now();
            let batches: Vec<Batch> = (0..k).map(|_| self.next_batch(agent, rng)).collect();
            self.run(|m| m.step_k(params, mom, &batches, &self.shape_x, &self.shape_y, lr))
                .expect("step_k artifact failed");
            let fused = t0.elapsed();
            let t1 = std::time::Instant::now();
            let mut measured_last = f64::NAN;
            for _ in 0..k {
                measured_last = self.step(agent, params, mom, lr, rng);
            }
            let unit = t1.elapsed();
            *verdict = Some(fused < unit);
            drop(verdict);
            let remaining = h - 2 * k;
            if remaining == 0 {
                // the measurement consumed the whole burst; honour the
                // "returns the last minibatch loss" contract
                return measured_last;
            }
            return self.step_burst(agent, params, mom, lr, remaining, rng);
        }
        let use_fused = verdict.unwrap_or(false) && k > 1;
        drop(verdict);
        let mut remaining = h;
        let mut last = f64::NAN;
        if use_fused {
            while remaining >= k {
                let batches: Vec<Batch> =
                    (0..k).map(|_| self.next_batch(agent, rng)).collect();
                last = self
                    .run(|m| m.step_k(params, mom, &batches, &self.shape_x, &self.shape_y, lr))
                    .expect("step_k artifact failed");
                remaining -= k;
            }
        }
        for _ in 0..remaining {
            last = self.step(agent, params, mom, lr, rng);
        }
        last
    }

    fn eval(&self, params: &[f32]) -> EvalResult {
        let batches = self.eval_batches();
        let mut loss = 0.0;
        let mut correct = 0.0;
        let denom = (batches.len() as f64) * self.labels_per_batch();
        for b in &batches {
            let (l, c) = self
                .run(|m| m.eval(params, b, &self.shape_x, &self.shape_y))
                .expect("eval artifact failed");
            loss += l;
            correct += c;
        }
        EvalResult {
            loss: loss / batches.len() as f64,
            accuracy: correct / denom,
        }
    }

    fn epochs(&self, agent: usize, steps: u64) -> f64 {
        steps as f64 * self.model.manifest.batch as f64 / self.shard_sizes[agent]
    }
}
