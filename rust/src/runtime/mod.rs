//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `make artifacts`) and exposes them as a [`TrainBackend`].
//!
//! Interchange is HLO **text**: jax ≥ 0.5 emits serialized protos with
//! 64-bit instruction ids that the linked xla_extension 0.5.1 rejects;
//! `HloModuleProto::from_text_file` re-parses and reassigns ids cleanly
//! (see /opt/xla-example/README.md and DESIGN.md §7.1).
//!
//! Python never runs here — the compiled executables are self-contained.

mod backend_xla;
mod manifest;
mod model;

pub use backend_xla::{XlaBackend, XlaBackendConfig};
pub use manifest::{load_manifest, ModelManifest};
pub use model::XlaModel;

use crate::backend::TrainBackend;

#[allow(dead_code)]
fn _object_safe(_: &dyn TrainBackend) {}
