//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `make artifacts`) and exposes them through the unified
//! [`Backend`] trait, so compiled models plug into the same
//! Algorithm × Executor matrix as the pure-Rust oracles.
//!
//! Interchange is HLO **text**: jax ≥ 0.5 emits serialized protos with
//! 64-bit instruction ids that the linked xla_extension 0.5.1 rejects;
//! `HloModuleProto::from_text_file` re-parses and reassigns ids cleanly
//! (see /opt/xla-example/README.md and DESIGN.md §7.1).
//!
//! Python never runs at training time — the compiled executables are
//! self-contained.
//!
//! **Feature gating:** the real PJRT path links against the xla-rs bindings
//! and a local `xla_extension`, neither of which exists in CI or a fresh
//! checkout. It therefore compiles only with `--features pjrt`; the default
//! build substitutes [`XlaBackend`] with a stub whose `load` fails with an
//! actionable error. Manifest parsing and [`XlaBackendConfig`] are pure Rust
//! and stay available unconditionally so configs, figures, and the CLI
//! type-check either way.

mod manifest;

pub use manifest::{find_preset, load_manifest, ModelManifest};

use crate::backend::Backend;
use crate::config::ShardMode;

/// Data-generation knobs for the XLA backend.
#[derive(Clone, Debug)]
pub struct XlaBackendConfig {
    pub agents: usize,
    /// training examples per agent (dense) / tokens per agent (LM)
    pub data_per_agent: usize,
    pub shard: ShardMode,
    /// Gaussian-mixture class separation
    pub separation: f32,
    pub seed: u64,
    /// held-out evaluation batches
    pub eval_batches: usize,
}

impl Default for XlaBackendConfig {
    fn default() -> Self {
        Self {
            agents: 8,
            data_per_agent: 512,
            shard: ShardMode::Iid,
            separation: 3.0,
            seed: 7,
            eval_batches: 4,
        }
    }
}

#[cfg(feature = "pjrt")]
mod backend_xla;
#[cfg(feature = "pjrt")]
mod model;

#[cfg(feature = "pjrt")]
pub use backend_xla::XlaBackend;
#[cfg(feature = "pjrt")]
pub use model::XlaModel;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{PjrtUnavailable, XlaBackend};

#[allow(dead_code)]
fn _object_safe(_: &dyn Backend) {}
