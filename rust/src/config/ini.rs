//! Minimal INI parser: `[section]` headers, `key = value` pairs,
//! `#`/`;` comments, blank lines.  Order-preserving.

use std::collections::HashMap;

#[derive(Clone, Debug, Default)]
pub struct IniSection {
    pub name: String,
    pub entries: HashMap<String, String>,
}

impl IniSection {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn parse<T: std::str::FromStr>(&self, key: &str) -> Option<T> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    pub fn require<T: std::str::FromStr>(&self, key: &str) -> Result<T, String> {
        self.get(key)
            .ok_or_else(|| format!("[{}] missing key '{key}'", self.name))?
            .parse()
            .map_err(|_| format!("[{}] key '{key}' unparseable", self.name))
    }
}

#[derive(Clone, Debug, Default)]
pub struct IniDoc {
    pub sections: Vec<IniSection>,
}

impl IniDoc {
    pub fn section(&self, name: &str) -> Option<&IniSection> {
        self.sections.iter().find(|s| s.name == name)
    }
}

/// Parse INI text. Keys outside any `[section]` go into a section named "".
pub fn parse_ini(text: &str) -> Result<IniDoc, String> {
    let mut doc = IniDoc::default();
    let mut current = IniSection { name: String::new(), entries: HashMap::new() };
    let mut started = false;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with(';') {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section header", lineno + 1))?;
            if started || !current.entries.is_empty() {
                doc.sections.push(std::mem::take(&mut current));
            }
            current.name = name.trim().to_string();
            started = true;
        } else if let Some((k, v)) = line.split_once('=') {
            current
                .entries
                .insert(k.trim().to_string(), v.trim().to_string());
        } else {
            return Err(format!("line {}: expected 'key = value', got '{line}'", lineno + 1));
        }
    }
    if started || !current.entries.is_empty() {
        doc.sections.push(current);
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_values() {
        let doc = parse_ini(
            "# comment\n[alpha]\nx = 1\nname = hello world\n\n[beta]\ny = 2.5\n",
        )
        .unwrap();
        assert_eq!(doc.sections.len(), 2);
        let a = doc.section("alpha").unwrap();
        assert_eq!(a.parse::<i32>("x"), Some(1));
        assert_eq!(a.get("name"), Some("hello world"));
        let b = doc.section("beta").unwrap();
        assert_eq!(b.parse::<f64>("y"), Some(2.5));
        assert!(doc.section("gamma").is_none());
    }

    #[test]
    fn top_level_keys() {
        let doc = parse_ini("k = v\n[s]\na = b\n").unwrap();
        assert_eq!(doc.sections[0].name, "");
        assert_eq!(doc.sections[0].get("k"), Some("v"));
    }

    #[test]
    fn errors_are_reported() {
        assert!(parse_ini("[unterminated\n").is_err());
        assert!(parse_ini("not a kv pair\n").is_err());
    }

    #[test]
    fn require_errors() {
        let doc = parse_ini("[s]\nx = notanumber\n").unwrap();
        let s = doc.section("s").unwrap();
        assert!(s.require::<i64>("x").is_err());
        assert!(s.require::<i64>("missing").is_err());
        assert_eq!(s.require::<String>("x").unwrap(), "notanumber");
    }
}
