//! Configuration: a dependency-free INI-subset parser (used for both run
//! configs and the artifact manifest) plus typed run-configuration structs
//! with named presets.

mod ini;
mod run;

pub use ini::{parse_ini, IniDoc, IniSection};
pub use run::{DataKind, RunConfig, ShardMode};
