//! Typed run configuration + parsing from INI files / CLI overrides.

use super::ini::parse_ini;
use crate::coordinator::{AveragingMode, LocalSteps, LrSchedule, WireCodec};
use crate::netmodel::CostModel;
use crate::topology::Topology;

/// How the training data is partitioned across agents (paper §5 / Appx H).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ShardMode {
    Iid,
    ByLabel,
    Dirichlet(f64),
}

/// Which input modality the chosen model consumes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataKind {
    Vector,
    Image,
    Tokens,
}

/// Complete description of one training run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// swarm | poisson | adpsgd | dpsgd | sgp | localsgd | allreduce
    /// (the `--algorithm` selector; orthogonal to `executor`)
    pub algo: String,
    /// artifact preset (mlp_s, cnn_s, cnn_m, transformer_s, transformer_m)
    /// or oracle:quadratic / oracle:quadratic-proc / oracle:softmax /
    /// oracle:logistic (`quadratic-proc` is the table-free twin for the
    /// scale regime)
    pub preset: String,
    pub n: usize,
    /// complete | ring | torus | hypercube | random<r> | regular<r> |
    /// powerlaw | powerlaw<m> | expander | expander<r> (`regular<r>` is an
    /// alias of `random<r>`; bare `powerlaw` uses attachment degree m=2;
    /// bare `expander` is the degree-8 random-circulant preset)
    pub topology: String,
    /// uniform | bimodal:<frac>:<slowdown> | pareto:<alpha> — per-node
    /// speed classes mapped onto Poisson clock rates (`--speeds`):
    /// `bimodal:0.25:4` makes a quarter of the nodes 4× slower;
    /// `pareto:2.5` draws heavy-tailed per-node slowdowns. Stragglers are
    /// *structural* (fixed per node for the whole run), unlike the i.i.d.
    /// per-step `straggler_prob` of the cost model.
    pub speeds: String,
    /// directed graph orientation for push-sum (`--directed`): sgp-only,
    /// on the orientable families (ring, torus, complete)
    pub directed: bool,
    /// time-varying topology: comma-separated `<topology>@<tick>` stages
    /// ("" = static `topology` for the whole run). The first stage must
    /// start at tick 0, e.g. `ring@0,torus@5000,complete@20000`.
    pub topology_schedule: String,
    /// total pairwise interactions (gossip) or rounds (synchronous)
    pub interactions: u64,
    /// mean local steps H
    pub h: f64,
    /// geometric H (Theorem 4.1) vs fixed H (Theorem 4.2)
    pub geometric: bool,
    /// blocking | nonblocking | quantized
    pub mode: String,
    /// f32 | lattice — the wire codec (`--wire`): whether model payloads
    /// cross the simulated wire at full precision or lattice-quantized
    /// (`quant_bits` / `quant_eps`), on every executor. `mode = quantized`
    /// implies the lattice codec for swarm/poisson and takes precedence
    /// over the default `wire = f32`; full precision is `mode =
    /// nonblocking`.
    pub wire: String,
    pub quant_bits: u32,
    pub quant_eps: f32,
    pub lr: f32,
    /// constant | step | theory
    pub lr_schedule: String,
    pub seed: u64,
    pub eval_every: u64,
    pub track_gamma: bool,
    pub shard: ShardMode,
    /// training examples per agent (synthetic generation)
    pub data_per_agent: usize,
    pub artifacts_dir: String,
    /// simulated compute seconds per local step
    pub batch_time: f64,
    pub jitter: f64,
    /// probability a local step straggles (multiplied by `straggle_factor`)
    pub straggler_prob: f64,
    pub straggle_factor: f64,
    /// p2p message latency (seconds)
    pub latency: f64,
    /// p2p effective bandwidth (bytes/second)
    pub bandwidth: f64,
    /// wire-size override in bytes for the simulated model (0 = native 4·d)
    pub model_bytes: u64,
    /// results CSV path ("" = don't write)
    pub out_csv: String,
    /// serial | parallel | freerun | cluster — which executor runs the
    /// algorithm. `serial`/`parallel` drain the pre-drawn schedule
    /// (bit-replayable); `freerun` is the free-running sharded runtime
    /// (throughput-faithful, non-replayable, algorithms with a
    /// `MixPolicy`: swarm, poisson, adpsgd, dpsgd, and — via weighted
    /// slots — sgp); `cluster` is the multi-process flavor of freerun
    /// (coordinator + socket-gossiping workers, `--role` required)
    pub executor: String,
    /// worker threads for the parallel/freerun executors. 0 is the
    /// *internal* "auto" default (one per core); explicitly setting
    /// `threads=0` is rejected at parse time with an actionable error,
    /// mirroring the `shards` treatment
    pub threads: usize,
    /// node shards for the freerun executor. 0 is the *internal* "auto"
    /// default (one shard per worker); explicitly setting `shards=0` is
    /// rejected at parse time with an actionable error
    pub shards: usize,
    /// scalar | simd — which fused merge-kernel implementation every
    /// interaction dispatches to (`--kernel`). Both are bit-exact, so this
    /// is a pure performance axis; `scalar` is the reference default.
    pub kernel: String,
    /// worker *processes* the cluster executor's coordinator registers
    /// before starting the job (`--workers`); unrelated to `threads`
    pub workers: usize,
    /// seconds without a heartbeat before the cluster coordinator declares
    /// a worker dead and reassigns its shard from the last checkpoint
    pub heartbeat_timeout: f64,
    /// Chrome trace-event JSON output path (`--trace-out`; "" = tracing
    /// off). Cluster workers suffix their rank before the extension.
    pub trace_out: String,
    /// fraction of interactions traced, in [0, 1] (`--trace-sample`);
    /// sampled deterministically per worker. 0 disables tracing even when
    /// `trace_out` is set; values outside [0, 1] are rejected at parse time
    pub trace_sample: f64,
    /// live-churn process for the freerun scale engine
    /// (`--churn join:<rate>,leave:<rate>`; "" = fixed roster). Negative or
    /// non-finite rates are rejected at parse time. Churn implies the
    /// compact node store and is (for now) incompatible with the cluster
    /// executor
    pub churn: String,
    /// auto | dense | compact — node-state storage for the freerun
    /// executor (`--node-store`). `dense` is the materialized per-node
    /// `NodeState` path; `compact` routes through the membership
    /// subsystem's lattice-encoded [`crate::membership::NodeStore`]; `auto`
    /// picks dense up to the materialize cutover and compact above it (or
    /// whenever churn is active)
    pub node_store: String,
    /// enforced resident-bytes-per-node budget for the compact store, in
    /// bytes (`--node-budget`; 0 = the internal "unenforced" default). A
    /// compact run whose per-node footprint would exceed the budget fails
    /// fast, before allocating the arena
    pub node_budget: u64,
    /// Prometheus text snapshot path (`--metrics-out`; "" = off); snapshots
    /// append at a fixed cadence, giving a time series instead of run-end
    /// totals
    pub metrics_out: String,
    /// HOST:PORT for the cluster coordinator's live introspection endpoint
    /// (`--metrics-addr`; "" = off) serving /metrics, /status, /trace
    pub metrics_addr: String,
    /// error | warn | info | debug (`--log-level`): the [`crate::obs::log`]
    /// threshold every diagnostic routes through
    pub log_level: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            algo: "swarm".into(),
            preset: "mlp_s".into(),
            n: 8,
            topology: "complete".into(),
            speeds: "uniform".into(),
            directed: false,
            topology_schedule: String::new(),
            interactions: 400,
            h: 2.0,
            geometric: false,
            mode: "nonblocking".into(),
            wire: "f32".into(),
            quant_bits: 8,
            quant_eps: 1e-3,
            lr: 0.05,
            lr_schedule: "constant".into(),
            seed: 42,
            eval_every: 50,
            track_gamma: false,
            shard: ShardMode::Iid,
            data_per_agent: 512,
            artifacts_dir: "artifacts".into(),
            batch_time: 0.4,
            jitter: 0.05,
            straggler_prob: 0.01,
            straggle_factor: 3.0,
            latency: 1.5e-6,
            bandwidth: 10.0e9,
            model_bytes: 0,
            out_csv: String::new(),
            executor: "serial".into(),
            threads: 0,
            shards: 0,
            kernel: "scalar".into(),
            workers: 2,
            heartbeat_timeout: 5.0,
            trace_out: String::new(),
            trace_sample: 1.0,
            churn: String::new(),
            node_store: "auto".into(),
            node_budget: 0,
            metrics_out: String::new(),
            metrics_addr: String::new(),
            log_level: "info".into(),
        }
    }
}

impl RunConfig {
    /// Parse from INI text (single `[run]` section or top-level keys).
    pub fn from_ini(text: &str) -> Result<Self, String> {
        let doc = parse_ini(text)?;
        let sec = doc
            .section("run")
            .or_else(|| doc.sections.first())
            .ok_or("empty config")?;
        let mut c = Self::default();
        for (k, v) in &sec.entries {
            c.set(k, v)?;
        }
        Ok(c)
    }

    /// Apply one `key=value` override (CLI `--set k=v` or INI entry).
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        let bad = |k: &str, v: &str| format!("bad value '{v}' for key '{k}'");
        match key {
            "algo" | "algorithm" => {
                if !crate::coordinator::ALGORITHM_NAMES.contains(&value) {
                    return Err(format!(
                        "unknown algorithm '{value}' (known: {})",
                        crate::coordinator::ALGORITHM_NAMES.join("|")
                    ));
                }
                self.algo = value.into();
            }
            "preset" => self.preset = value.into(),
            "n" => self.n = value.parse().map_err(|_| bad(key, value))?,
            "topology" => {
                // parse eagerly so a typo'd family name errors here (with
                // the known names) instead of deep in run setup, and never
                // clobbers the prior value
                Topology::parse(value)?;
                self.topology = value.into();
            }
            "speeds" => {
                crate::scenario::SpeedClass::parse(value)?;
                self.speeds = value.into();
            }
            "directed" => self.directed = value.parse().map_err(|_| bad(key, value))?,
            "topology_schedule" | "topology-schedule" => {
                crate::scenario::parse_topology_schedule(value)?;
                self.topology_schedule = value.into();
            }
            "dirichlet" => {
                // CLI sugar: `--dirichlet 0.3` == `shard=dirichlet:0.3`
                let a: f64 = value.parse().map_err(|_| bad(key, value))?;
                if !a.is_finite() || a <= 0.0 {
                    return Err(format!(
                        "dirichlet alpha must be a positive number (got '{value}'); \
                         small alpha = heavy label skew, large alpha = ~iid"
                    ));
                }
                self.shard = ShardMode::Dirichlet(a);
            }
            "interactions" | "rounds" => {
                self.interactions = value.parse().map_err(|_| bad(key, value))?
            }
            "h" | "local_steps" => self.h = value.parse().map_err(|_| bad(key, value))?,
            "geometric" => self.geometric = value.parse().map_err(|_| bad(key, value))?,
            "mode" => self.mode = value.into(),
            "wire" => match value {
                "f32" | "lattice" => self.wire = value.into(),
                _ => {
                    return Err(format!(
                        "bad value '{value}' for key 'wire' (want f32 or lattice)"
                    ))
                }
            },
            "quant_bits" => self.quant_bits = value.parse().map_err(|_| bad(key, value))?,
            "quant_eps" => self.quant_eps = value.parse().map_err(|_| bad(key, value))?,
            "lr" => self.lr = value.parse().map_err(|_| bad(key, value))?,
            "lr_schedule" => self.lr_schedule = value.into(),
            "seed" => self.seed = value.parse().map_err(|_| bad(key, value))?,
            "eval_every" => self.eval_every = value.parse().map_err(|_| bad(key, value))?,
            "track_gamma" => {
                self.track_gamma = value.parse().map_err(|_| bad(key, value))?
            }
            "shard" => {
                self.shard = match value {
                    "iid" => ShardMode::Iid,
                    "label" => ShardMode::ByLabel,
                    v if v.starts_with("dirichlet:") => {
                        let a = v["dirichlet:".len()..]
                            .parse()
                            .map_err(|_| bad(key, value))?;
                        ShardMode::Dirichlet(a)
                    }
                    _ => return Err(bad(key, value)),
                }
            }
            "data_per_agent" => {
                self.data_per_agent = value.parse().map_err(|_| bad(key, value))?
            }
            "artifacts_dir" => self.artifacts_dir = value.into(),
            "batch_time" => self.batch_time = value.parse().map_err(|_| bad(key, value))?,
            "jitter" => self.jitter = value.parse().map_err(|_| bad(key, value))?,
            "straggler_prob" => {
                self.straggler_prob = value.parse().map_err(|_| bad(key, value))?
            }
            "straggle_factor" => {
                self.straggle_factor = value.parse().map_err(|_| bad(key, value))?
            }
            "latency" => self.latency = value.parse().map_err(|_| bad(key, value))?,
            "bandwidth" => self.bandwidth = value.parse().map_err(|_| bad(key, value))?,
            "model_bytes" | "model_bytes_override" => {
                self.model_bytes = value.parse().map_err(|_| bad(key, value))?
            }
            "out_csv" => self.out_csv = value.into(),
            "executor" => match value {
                "serial" | "parallel" | "freerun" | "cluster" => self.executor = value.into(),
                _ => {
                    return Err(format!(
                        "bad value '{value}' for key 'executor' \
                         (want serial, parallel, freerun, or cluster)"
                    ))
                }
            },
            "threads" => {
                let t: usize = value.parse().map_err(|_| bad(key, value))?;
                if t == 0 {
                    return Err(
                        "threads must be >= 1; omit the key (or the --threads flag) \
                         to default to one worker per core"
                            .to_string(),
                    );
                }
                self.threads = t;
            }
            "shards" => {
                let s: usize = value.parse().map_err(|_| bad(key, value))?;
                if s == 0 {
                    return Err(
                        "shards must be >= 1; omit the key (or the --shards flag) \
                         to default to one shard per worker thread"
                            .to_string(),
                    );
                }
                self.shards = s;
            }
            "kernel" => match value {
                "scalar" | "simd" => self.kernel = value.into(),
                _ => {
                    return Err(format!(
                        "bad value '{value}' for key 'kernel' (want scalar or simd)"
                    ))
                }
            },
            "workers" => {
                let w: usize = value.parse().map_err(|_| bad(key, value))?;
                if w == 0 {
                    return Err(
                        "workers must be >= 1; omit the key (or the --workers flag) \
                         to default to 2 cluster worker processes"
                            .to_string(),
                    );
                }
                self.workers = w;
            }
            "heartbeat_timeout" | "heartbeat-timeout" => {
                let t: f64 = value.parse().map_err(|_| bad(key, value))?;
                if !t.is_finite() || t <= 0.0 {
                    return Err(format!(
                        "heartbeat_timeout must be a positive number of seconds \
                         (got '{value}'); omit the key to default to 5"
                    ));
                }
                self.heartbeat_timeout = t;
            }
            "trace_out" | "trace-out" => self.trace_out = value.into(),
            "trace_sample" | "trace-sample" => {
                let s: f64 = value.parse().map_err(|_| bad(key, value))?;
                if !s.is_finite() || !(0.0..=1.0).contains(&s) {
                    return Err(format!(
                        "trace_sample must be in [0, 1] (got '{value}'); 0 \
                         disables tracing, omit the key to trace every \
                         interaction"
                    ));
                }
                self.trace_sample = s;
            }
            "churn" => {
                // eager validation, same contract as topology/speeds: a
                // negative rate or a typo'd part errors here with the
                // actionable ChurnSpec message and never clobbers
                crate::membership::ChurnSpec::parse(value)?;
                self.churn = value.trim().into();
            }
            "node_store" | "node-store" => match value {
                "auto" | "dense" | "compact" => self.node_store = value.into(),
                _ => {
                    return Err(format!(
                        "bad value '{value}' for key 'node_store' \
                         (want auto, dense, or compact)"
                    ))
                }
            },
            "node_budget" | "node-budget" => {
                let b: u64 = value.parse().map_err(|_| bad(key, value))?;
                if b == 0 {
                    return Err(
                        "node_budget must be >= 1 byte; omit the key (or the \
                         --node-budget flag) to leave the bytes-per-node \
                         budget unenforced"
                            .to_string(),
                    );
                }
                self.node_budget = b;
            }
            "metrics_out" | "metrics-out" => self.metrics_out = value.into(),
            "metrics_addr" | "metrics-addr" => self.metrics_addr = value.into(),
            "log_level" | "log-level" => {
                // normalize through the parser so aliases ("warning")
                // serialize canonically and bad values never clobber
                self.log_level = crate::obs::log::Level::parse(value)?.name().into();
            }
            _ => return Err(format!("unknown config key '{key}'")),
        }
        Ok(())
    }

    pub fn topology_enum(&self) -> Result<Topology, String> {
        Topology::parse(&self.topology)
    }

    /// The parsed churn process ("" = the inactive fixed-roster spec).
    pub fn churn_spec(&self) -> Result<crate::membership::ChurnSpec, String> {
        crate::membership::ChurnSpec::parse(&self.churn)
    }

    /// Whether a `freerun` run routes to the membership scale engine
    /// instead of the dense freerun executor: churn demands the compact
    /// store, `node_store = compact` forces it, `node_store = dense`
    /// forbids it (an error when churn is also on), and `auto` switches at
    /// the materialize cutover
    /// ([`crate::membership::MATERIALIZE_MAX`] nodes).
    pub fn scale_engine_selected(&self) -> Result<bool, String> {
        let churn = self.churn_spec()?.active();
        Ok(match self.node_store.as_str() {
            "compact" => true,
            "dense" => {
                if churn {
                    return Err(
                        "churn requires the compact node store; drop \
                         node_store=dense (or the --churn flag) to proceed"
                            .to_string(),
                    );
                }
                false
            }
            _ => churn || self.n > crate::membership::MATERIALIZE_MAX,
        })
    }

    pub fn local_steps(&self) -> LocalSteps {
        if self.geometric {
            LocalSteps::Geometric(self.h)
        } else {
            LocalSteps::Fixed(self.h.round().max(1.0) as u64)
        }
    }

    pub fn averaging_mode(&self) -> Result<AveragingMode, String> {
        Ok(match self.mode.as_str() {
            "blocking" => AveragingMode::Blocking,
            "nonblocking" => AveragingMode::NonBlocking,
            "quantized" => AveragingMode::Quantized {
                bits: self.quant_bits,
                eps: self.quant_eps,
            },
            m => return Err(format!("unknown averaging mode '{m}'")),
        })
    }

    /// The wire codec (`--wire`): lattice quantization draws its `bits` /
    /// `eps` from the `quant_bits` / `quant_eps` keys.
    pub fn wire_codec(&self) -> Result<WireCodec, String> {
        Ok(match self.wire.as_str() {
            "f32" => WireCodec::F32,
            "lattice" => WireCodec::Lattice { bits: self.quant_bits, eps: self.quant_eps },
            w => return Err(format!("unknown wire codec '{w}' (want f32 or lattice)")),
        })
    }

    /// The fused merge-kernel selector (`--kernel scalar|simd`).
    pub fn kernel_enum(&self) -> Result<crate::kernels::Kernel, String> {
        crate::kernels::Kernel::parse(&self.kernel)
    }

    pub fn lr_schedule_enum(&self) -> Result<LrSchedule, String> {
        Ok(match self.lr_schedule.as_str() {
            "constant" => LrSchedule::Constant(self.lr),
            "step" => LrSchedule::StepDecay { base: self.lr, total: self.interactions },
            "theory" => LrSchedule::Theory { n: self.n, t: self.interactions },
            s => return Err(format!("unknown lr schedule '{s}'")),
        })
    }

    /// Fully configured [`CostModel`] — every knob is INI/CLI-reachable
    /// (defaults match `CostModel::default()`, so omitting keys is neutral).
    pub fn cost_model(&self) -> CostModel {
        CostModel {
            batch_time: self.batch_time,
            jitter: self.jitter,
            straggler_prob: self.straggler_prob,
            straggle_factor: self.straggle_factor,
            latency: self.latency,
            bandwidth: self.bandwidth,
            model_bytes_override: if self.model_bytes > 0 {
                Some(self.model_bytes)
            } else {
                None
            },
        }
    }

    /// Serialize to INI text that [`RunConfig::from_ini`] parses back to an
    /// identical config — how the cluster coordinator distributes the run
    /// config to its workers (one frame, no shared filesystem assumed).
    pub fn to_ini(&self) -> String {
        let shard = match self.shard {
            ShardMode::Iid => "iid".to_string(),
            ShardMode::ByLabel => "label".to_string(),
            ShardMode::Dirichlet(a) => format!("dirichlet:{a}"),
        };
        let mut out = String::from("[run]\n");
        let mut put = |k: &str, v: String| {
            out.push_str(k);
            out.push_str(" = ");
            out.push_str(&v);
            out.push('\n');
        };
        put("algo", self.algo.clone());
        put("preset", self.preset.clone());
        put("n", self.n.to_string());
        put("topology", self.topology.clone());
        put("speeds", self.speeds.clone());
        put("directed", self.directed.to_string());
        put("interactions", self.interactions.to_string());
        put("h", self.h.to_string());
        put("geometric", self.geometric.to_string());
        put("mode", self.mode.clone());
        put("wire", self.wire.clone());
        put("quant_bits", self.quant_bits.to_string());
        put("quant_eps", self.quant_eps.to_string());
        put("lr", self.lr.to_string());
        put("lr_schedule", self.lr_schedule.clone());
        put("seed", self.seed.to_string());
        put("eval_every", self.eval_every.to_string());
        put("track_gamma", self.track_gamma.to_string());
        put("shard", shard);
        put("data_per_agent", self.data_per_agent.to_string());
        put("artifacts_dir", self.artifacts_dir.clone());
        put("batch_time", self.batch_time.to_string());
        put("jitter", self.jitter.to_string());
        put("straggler_prob", self.straggler_prob.to_string());
        put("straggle_factor", self.straggle_factor.to_string());
        put("latency", self.latency.to_string());
        put("bandwidth", self.bandwidth.to_string());
        put("model_bytes", self.model_bytes.to_string());
        put("executor", self.executor.clone());
        // threads/shards 0 is the internal auto default that set() rejects
        // as an explicit value, so only non-default values are written
        if self.threads > 0 {
            put("threads", self.threads.to_string());
        }
        if self.shards > 0 {
            put("shards", self.shards.to_string());
        }
        put("kernel", self.kernel.clone());
        put("workers", self.workers.to_string());
        put("heartbeat_timeout", self.heartbeat_timeout.to_string());
        put("trace_sample", self.trace_sample.to_string());
        put("node_store", self.node_store.clone());
        // node_budget 0 is the internal "unenforced" default that set()
        // rejects as an explicit value, mirroring threads/shards
        if self.node_budget > 0 {
            put("node_budget", self.node_budget.to_string());
        }
        if !self.churn.is_empty() {
            put("churn", self.churn.clone());
        }
        put("log_level", self.log_level.clone());
        if !self.out_csv.is_empty() {
            put("out_csv", self.out_csv.clone());
        }
        // path/addr keys follow the out_csv pattern: "" means off, and an
        // empty value is never written (set() treats presence as intent)
        if !self.topology_schedule.is_empty() {
            put("topology_schedule", self.topology_schedule.clone());
        }
        if !self.trace_out.is_empty() {
            put("trace_out", self.trace_out.clone());
        }
        if !self.metrics_out.is_empty() {
            put("metrics_out", self.metrics_out.clone());
        }
        if !self.metrics_addr.is_empty() {
            put("metrics_addr", self.metrics_addr.clone());
        }
        out
    }

    /// The observability switches this config implies — the one place
    /// `trace_out`/`trace_sample`/`metrics_out` become executor options
    /// (used by `main` for in-process runs and by cluster workers, which
    /// receive this config over the wire).
    pub fn obs_options(&self) -> crate::obs::ObsOptions {
        crate::obs::ObsOptions {
            // trace_sample = 0 means "trace nothing" at the config level;
            // ObsOptions keeps 0.0 as its own unset default, so the off
            // state maps to a zero-capacity ring rather than rate 0
            trace_capacity: if self.trace_out.is_empty() || self.trace_sample == 0.0 {
                0
            } else {
                crate::obs::DEFAULT_TRACE_CAPACITY
            },
            trace_sample: self.trace_sample,
            metrics_out: if self.metrics_out.is_empty() {
                None
            } else {
                Some(self.metrics_out.clone())
            },
        }
    }

    /// Simulated-wire knobs that were explicitly moved off their defaults —
    /// the ones the cluster executor *ignores* (its gossip crosses real
    /// sockets, so `latency`/`bandwidth`/`model_bytes` have nothing to
    /// scale). The CLI prints a one-line warning naming these when
    /// `--executor cluster` runs; compute-side knobs (`batch_time`,
    /// `jitter`, stragglers) still apply everywhere.
    pub fn simulated_wire_overrides(&self) -> Vec<&'static str> {
        let d = Self::default();
        let mut over = Vec::new();
        if self.latency != d.latency {
            over.push("latency");
        }
        if self.bandwidth != d.bandwidth {
            over.push("bandwidth");
        }
        if self.model_bytes != d.model_bytes {
            over.push("model_bytes");
        }
        over
    }

    pub fn is_oracle(&self) -> bool {
        self.preset.starts_with("oracle:")
    }

    /// Worker-thread count for the parallel executor: the configured value,
    /// or one per available core when left at 0 ("auto").
    pub fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
        }
    }

    /// Node-shard count for the freerun executor: the configured value, or
    /// one shard per worker thread when left at 0 ("auto"). The executor
    /// clamps to `[1, n]`.
    pub fn effective_shards(&self) -> usize {
        if self.shards > 0 {
            self.shards
        } else {
            self.effective_threads()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_consistent() {
        let c = RunConfig::default();
        assert!(c.topology_enum().is_ok());
        assert!(c.averaging_mode().is_ok());
        assert!(c.lr_schedule_enum().is_ok());
        assert!(!c.is_oracle());
    }

    #[test]
    fn ini_roundtrip() {
        let c = RunConfig::from_ini(
            "[run]\nalgo = adpsgd\nn = 16\ntopology = random4\nh = 3\n\
             mode = quantized\nquant_bits = 6\nshard = dirichlet:0.3\nlr = 0.1\n",
        )
        .unwrap();
        assert_eq!(c.algo, "adpsgd");
        assert_eq!(c.n, 16);
        assert_eq!(c.topology_enum().unwrap(), Topology::RandomRegular(4));
        assert_eq!(c.shard, ShardMode::Dirichlet(0.3));
        match c.averaging_mode().unwrap() {
            AveragingMode::Quantized { bits, .. } => assert_eq!(bits, 6),
            m => panic!("wrong mode {m:?}"),
        }
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = RunConfig::default();
        assert!(c.set("definitely_not_a_key", "1").is_err());
        assert!(c.set("n", "not_a_number").is_err());
    }

    #[test]
    fn algorithm_key_is_validated_and_aliased() {
        let mut c = RunConfig::default();
        for name in crate::coordinator::ALGORITHM_NAMES {
            c.set("algorithm", name).unwrap();
            assert_eq!(&c.algo, name);
        }
        c.set("algo", "sgp").unwrap();
        assert_eq!(c.algo, "sgp");
        assert!(c.set("algorithm", "sgdx").is_err());
        assert!(c.set("algo", "").is_err());
    }

    #[test]
    fn oracle_detection() {
        let mut c = RunConfig::default();
        c.preset = "oracle:quadratic".into();
        assert!(c.is_oracle());
    }

    #[test]
    fn executor_keys_parse_and_validate() {
        let mut c = RunConfig::default();
        assert_eq!(c.executor, "serial");
        c.set("executor", "parallel").unwrap();
        c.set("threads", "4").unwrap();
        assert_eq!(c.executor, "parallel");
        assert_eq!(c.threads, 4);
        assert_eq!(c.effective_threads(), 4);
        assert!(c.set("executor", "gpu").is_err());
        assert!(c.set("threads", "many").is_err());
        // the unset default (0) still means auto — one worker per core
        assert!(RunConfig::default().effective_threads() >= 1);
    }

    #[test]
    fn explicit_zero_threads_is_an_actionable_error() {
        // mirrors the shards=0 treatment: 0 is only the internal "auto"
        // default; writing it explicitly (CLI --threads 0 or INI
        // threads = 0) is rejected, and the prior value is left untouched
        let mut c = RunConfig::default();
        c.set("threads", "4").unwrap();
        let err = c.set("threads", "0").unwrap_err();
        assert!(err.contains("threads must be >= 1"), "unhelpful error: {err}");
        assert_eq!(c.threads, 4);
        let err = RunConfig::from_ini("[run]\nthreads = 0\n").unwrap_err();
        assert!(err.contains("threads must be >= 1"), "unhelpful error: {err}");
    }

    #[test]
    fn wire_codec_key_parses_and_validates() {
        let mut c = RunConfig::default();
        assert_eq!(c.wire, "f32");
        assert_eq!(c.wire_codec().unwrap(), WireCodec::F32);
        c.set("wire", "lattice").unwrap();
        c.set("quant_bits", "6").unwrap();
        c.set("quant_eps", "0.01").unwrap();
        match c.wire_codec().unwrap() {
            WireCodec::Lattice { bits, eps } => {
                assert_eq!(bits, 6);
                assert!((eps - 0.01).abs() < 1e-9);
            }
            w => panic!("wrong codec {w:?}"),
        }
        let err = c.set("wire", "fp16").unwrap_err();
        assert!(err.contains("f32 or lattice"), "unhelpful error: {err}");
        assert_eq!(c.wire, "lattice", "bad value must not clobber the setting");
    }

    #[test]
    fn kernel_key_parses_and_validates() {
        use crate::kernels::Kernel;
        let mut c = RunConfig::default();
        assert_eq!(c.kernel, "scalar");
        assert_eq!(c.kernel_enum().unwrap(), Kernel::Scalar);
        c.set("kernel", "simd").unwrap();
        assert_eq!(c.kernel_enum().unwrap(), Kernel::Simd);
        let err = c.set("kernel", "avx1024").unwrap_err();
        assert!(err.contains("scalar or simd"), "unhelpful error: {err}");
        assert_eq!(c.kernel, "simd", "bad value must not clobber the setting");
        let c = RunConfig::from_ini("[run]\nkernel = scalar\n").unwrap();
        assert_eq!(c.kernel_enum().unwrap(), Kernel::Scalar);
        assert!(RunConfig::from_ini("[run]\nkernel = gpu\n").is_err());
    }

    #[test]
    fn freerun_executor_and_shards_parse() {
        let mut c = RunConfig::default();
        c.set("executor", "freerun").unwrap();
        assert_eq!(c.executor, "freerun");
        c.set("threads", "4").unwrap();
        assert_eq!(c.effective_shards(), 4, "shards default to one per worker");
        c.set("shards", "16").unwrap();
        assert_eq!(c.effective_shards(), 16);
        assert!(c.set("shards", "lots").is_err());
        // explicit shards=0 is rejected with an actionable message, not
        // silently clamped; the prior value is left untouched
        let err = c.set("shards", "0").unwrap_err();
        assert!(err.contains("shards must be >= 1"), "unhelpful error: {err}");
        assert_eq!(c.shards, 16);
    }

    #[test]
    fn cluster_executor_value_parses() {
        let mut c = RunConfig::default();
        c.set("executor", "cluster").unwrap();
        assert_eq!(c.executor, "cluster");
        let err = RunConfig::default().set("executor", "mpi").unwrap_err();
        assert!(err.contains("cluster"), "error should list the cluster value: {err}");
    }

    #[test]
    fn workers_key_validates_like_threads() {
        let mut c = RunConfig::default();
        assert_eq!(c.workers, 2);
        c.set("workers", "3").unwrap();
        assert_eq!(c.workers, 3);
        // explicit workers=0 is rejected with an actionable message and
        // must not clobber the prior value — mirrors threads=0/shards=0
        let err = c.set("workers", "0").unwrap_err();
        assert!(err.contains("workers must be >= 1"), "unhelpful error: {err}");
        assert_eq!(c.workers, 3);
        assert!(c.set("workers", "many").is_err());
        let err = RunConfig::from_ini("[run]\nworkers = 0\n").unwrap_err();
        assert!(err.contains("workers must be >= 1"), "unhelpful error: {err}");
    }

    #[test]
    fn heartbeat_timeout_rejects_nonpositive_and_nonfinite() {
        let mut c = RunConfig::default();
        assert_eq!(c.heartbeat_timeout, 5.0);
        c.set("heartbeat_timeout", "1.5").unwrap();
        assert_eq!(c.heartbeat_timeout, 1.5);
        // the hyphenated CLI spelling maps to the same key
        c.set("heartbeat-timeout", "2").unwrap();
        assert_eq!(c.heartbeat_timeout, 2.0);
        for bad in ["0", "-1", "nan", "inf", "soon"] {
            let err = c.set("heartbeat_timeout", bad).unwrap_err();
            assert!(
                err.contains("heartbeat_timeout") || err.contains("bad value"),
                "unhelpful error for '{bad}': {err}"
            );
            assert_eq!(c.heartbeat_timeout, 2.0, "bad '{bad}' must not clobber");
        }
    }

    #[test]
    fn to_ini_roundtrips_every_field() {
        let mut c = RunConfig::default();
        for (k, v) in [
            ("algo", "sgp"),
            ("preset", "oracle:quadratic"),
            ("n", "24"),
            ("topology", "random4"),
            ("speeds", "bimodal:0.25:4"),
            ("directed", "false"),
            ("topology_schedule", "ring@0,torus@500"),
            ("interactions", "1234"),
            ("h", "2.5"),
            ("geometric", "true"),
            ("mode", "quantized"),
            ("wire", "lattice"),
            ("quant_bits", "6"),
            ("quant_eps", "0.002"),
            ("lr", "0.07"),
            ("lr_schedule", "step"),
            ("seed", "77"),
            ("eval_every", "100"),
            ("track_gamma", "true"),
            ("shard", "dirichlet:0.3"),
            ("data_per_agent", "64"),
            ("batch_time", "0.1"),
            ("latency", "0.0001"),
            ("executor", "cluster"),
            ("threads", "3"),
            ("shards", "6"),
            ("kernel", "simd"),
            ("workers", "3"),
            ("heartbeat_timeout", "1.5"),
            ("trace_out", "trace.json"),
            ("trace_sample", "0.25"),
            ("metrics_out", "metrics.prom"),
            ("metrics_addr", "127.0.0.1:9090"),
            ("churn", "join:0.001,leave:0.002"),
            ("node_store", "compact"),
            ("node_budget", "512"),
            ("log_level", "debug"),
        ] {
            c.set(k, v).unwrap();
        }
        let back = RunConfig::from_ini(&c.to_ini()).unwrap();
        assert_eq!(format!("{back:?}"), format!("{c:?}"));
        // defaults round-trip too (threads/shards stay at the auto 0)
        let d = RunConfig::default();
        let back = RunConfig::from_ini(&d.to_ini()).unwrap();
        assert_eq!(format!("{back:?}"), format!("{d:?}"));
        assert_eq!(back.threads, 0);
    }

    #[test]
    fn topology_key_validates_aliases_and_never_clobbers() {
        let mut c = RunConfig::default();
        c.set("topology", "regular4").unwrap();
        assert_eq!(c.topology_enum().unwrap(), Topology::RandomRegular(4));
        c.set("topology", "powerlaw").unwrap();
        assert_eq!(c.topology_enum().unwrap(), Topology::PowerLaw(2));
        c.set("topology", "powerlaw3").unwrap();
        assert_eq!(c.topology_enum().unwrap(), Topology::PowerLaw(3));
        let err = c.set("topology", "smallworld").unwrap_err();
        assert!(err.contains("powerlaw"), "error should list known families: {err}");
        assert_eq!(c.topology, "powerlaw3", "bad value must not clobber");
    }

    #[test]
    fn speeds_key_validates_and_never_clobbers() {
        let mut c = RunConfig::default();
        assert_eq!(c.speeds, "uniform");
        c.set("speeds", "bimodal:0.25:4").unwrap();
        assert_eq!(c.speeds, "bimodal:0.25:4");
        c.set("speeds", "pareto:2.5").unwrap();
        for bad in ["warp", "bimodal:2:4", "bimodal:0.5:0", "pareto:-1", "pareto:x"] {
            let err = c.set("speeds", bad).unwrap_err();
            assert!(
                err.contains("speeds") || err.contains("bimodal") || err.contains("pareto"),
                "unhelpful error for '{bad}': {err}"
            );
            assert_eq!(c.speeds, "pareto:2.5", "bad '{bad}' must not clobber");
        }
    }

    #[test]
    fn topology_schedule_key_validates_format() {
        let mut c = RunConfig::default();
        c.set("topology_schedule", "ring@0,torus@500").unwrap();
        assert_eq!(c.topology_schedule, "ring@0,torus@500");
        for bad in ["ring@5", "ring", "ring@0,torus@0", "nope@0", "torus@500,ring@0"] {
            assert!(c.set("topology_schedule", bad).is_err(), "'{bad}' should be rejected");
            assert_eq!(c.topology_schedule, "ring@0,torus@500");
        }
    }

    #[test]
    fn dirichlet_key_is_shard_sugar() {
        let mut c = RunConfig::default();
        c.set("dirichlet", "0.3").unwrap();
        assert_eq!(c.shard, ShardMode::Dirichlet(0.3));
        for bad in ["0", "-1", "nan", "skewed"] {
            assert!(c.set("dirichlet", bad).is_err(), "'{bad}' should be rejected");
            assert_eq!(c.shard, ShardMode::Dirichlet(0.3));
        }
    }

    #[test]
    fn obs_keys_parse_validate_and_map_to_options() {
        let mut c = RunConfig::default();
        assert_eq!(c.log_level, "info");
        assert_eq!(c.trace_sample, 1.0);
        let opts = c.obs_options();
        assert_eq!(opts.trace_capacity, 0, "no trace_out means tracing off");
        assert!(opts.metrics_out.is_none());

        c.set("trace-out", "trace.json").unwrap();
        c.set("trace_sample", "0.5").unwrap();
        c.set("metrics-out", "m.prom").unwrap();
        c.set("metrics_addr", "127.0.0.1:0").unwrap();
        c.set("log-level", "warning").unwrap();
        assert_eq!(c.log_level, "warn", "aliases normalize");
        let opts = c.obs_options();
        assert_eq!(opts.trace_capacity, crate::obs::DEFAULT_TRACE_CAPACITY);
        assert_eq!(opts.sample_rate(), 0.5);
        assert_eq!(opts.metrics_out.as_deref(), Some("m.prom"));

        // bad values are actionable and never clobber
        for bad in ["-0.1", "1.5", "nan", "inf", "lots"] {
            let err = c.set("trace_sample", bad).unwrap_err();
            assert!(
                err.contains("trace_sample") || err.contains("bad value"),
                "unhelpful error for '{bad}': {err}"
            );
            assert_eq!(c.trace_sample, 0.5, "bad '{bad}' must not clobber");
        }
        // 0 is *in* range — "trace nothing" — and turns the ring off even
        // with trace_out set, rather than flipping to ObsOptions' unset
        // "trace everything" default
        c.set("trace_sample", "0").unwrap();
        assert_eq!(c.trace_sample, 0.0);
        assert_eq!(c.obs_options().trace_capacity, 0);
        let err = c.set("log_level", "verbose").unwrap_err();
        assert!(err.contains("error | warn | info | debug"), "unhelpful: {err}");
        assert_eq!(c.log_level, "warn");
    }

    #[test]
    fn churn_key_validates_eagerly_and_never_clobbers() {
        let mut c = RunConfig::default();
        assert_eq!(c.churn, "");
        assert!(!c.churn_spec().unwrap().active());
        c.set("churn", "join:0.001,leave:0.002").unwrap();
        let spec = c.churn_spec().unwrap();
        assert_eq!(spec.join, 0.001);
        assert_eq!(spec.leave, 0.002);
        assert!(spec.active());
        // negative / non-finite / typo'd specs fail with the ChurnSpec
        // message (">= 0", "--churn", known-parts), mirroring threads=0
        for bad in ["join:-0.1", "leave:nan", "jion:0.1", "join=0.1", "join:lots"] {
            let err = c.set("churn", bad).unwrap_err();
            assert!(
                err.contains(">= 0")
                    || err.contains("finite")
                    || err.contains("churn"),
                "unhelpful error for '{bad}': {err}"
            );
            assert_eq!(c.churn, "join:0.001,leave:0.002", "bad '{bad}' must not clobber");
        }
        // the hyphen-free CLI flag spelling and INI key are the same key
        let parsed = RunConfig::from_ini("[run]\nchurn = leave:0.5\n").unwrap();
        assert_eq!(parsed.churn_spec().unwrap().leave, 0.5);
        assert!(RunConfig::from_ini("[run]\nchurn = join:-1\n").is_err());
    }

    #[test]
    fn node_store_and_budget_keys_validate() {
        let mut c = RunConfig::default();
        assert_eq!(c.node_store, "auto");
        assert_eq!(c.node_budget, 0);
        for v in ["dense", "compact", "auto"] {
            c.set("node_store", v).unwrap();
            assert_eq!(c.node_store, v);
        }
        let err = c.set("node-store", "sparse").unwrap_err();
        assert!(err.contains("auto, dense, or compact"), "unhelpful: {err}");
        assert_eq!(c.node_store, "auto", "bad value must not clobber");

        c.set("node-budget", "256").unwrap();
        assert_eq!(c.node_budget, 256);
        // explicit 0 is rejected like threads=0: 0 is only the internal
        // "unenforced" default
        let err = c.set("node_budget", "0").unwrap_err();
        assert!(err.contains("node_budget must be >= 1"), "unhelpful: {err}");
        assert_eq!(c.node_budget, 256);
        assert!(c.set("node_budget", "lots").is_err());
    }

    #[test]
    fn scale_engine_routing_follows_store_churn_and_n() {
        let mut c = RunConfig::default();
        // small n, no churn, auto store → dense freerun
        assert!(!c.scale_engine_selected().unwrap());
        // above the materialize cutover, auto flips to the scale engine
        c.n = crate::membership::MATERIALIZE_MAX + 1;
        assert!(c.scale_engine_selected().unwrap());
        // dense is an explicit opt-out at any n...
        c.set("node_store", "dense").unwrap();
        assert!(!c.scale_engine_selected().unwrap());
        // ...but conflicts with churn, which needs the compact store
        c.set("churn", "join:0.01,leave:0.01").unwrap();
        let err = c.scale_engine_selected().unwrap_err();
        assert!(err.contains("compact node store"), "unhelpful: {err}");
        // churn alone selects the engine even at tiny n
        let mut c = RunConfig::default();
        c.set("churn", "leave:0.1").unwrap();
        assert!(c.scale_engine_selected().unwrap());
        // compact forces the engine at tiny n too
        let mut c = RunConfig::default();
        c.set("node_store", "compact").unwrap();
        assert!(c.scale_engine_selected().unwrap());
    }

    #[test]
    fn simulated_wire_overrides_name_only_moved_knobs() {
        let mut c = RunConfig::default();
        assert!(c.simulated_wire_overrides().is_empty());
        c.set("latency", "1e-4").unwrap();
        c.set("model_bytes", "45000000").unwrap();
        assert_eq!(c.simulated_wire_overrides(), vec!["latency", "model_bytes"]);
        // compute-side knobs are not wire knobs — they still apply on the
        // cluster executor and must not be flagged
        c.set("batch_time", "0.01").unwrap();
        assert_eq!(c.simulated_wire_overrides(), vec!["latency", "model_bytes"]);
    }

    #[test]
    fn cost_model_knobs_are_fully_wired() {
        // defaults must reproduce CostModel::default() exactly, so configs
        // that omit the keys keep their pre-existing behavior
        let d = RunConfig::default().cost_model();
        let want = CostModel::default();
        assert_eq!(d.batch_time, want.batch_time);
        assert_eq!(d.jitter, want.jitter);
        assert_eq!(d.straggler_prob, want.straggler_prob);
        assert_eq!(d.straggle_factor, want.straggle_factor);
        assert_eq!(d.latency, want.latency);
        assert_eq!(d.bandwidth, want.bandwidth);
        assert_eq!(d.model_bytes_override, want.model_bytes_override);

        let c = RunConfig::from_ini(
            "[run]\nstraggler_prob = 0.2\nstraggle_factor = 5\nlatency = 1e-4\n\
             bandwidth = 1e9\nmodel_bytes = 45000000\nbatch_time = 0.1\njitter = 0\n",
        )
        .unwrap();
        let m = c.cost_model();
        assert_eq!(m.straggler_prob, 0.2);
        assert_eq!(m.straggle_factor, 5.0);
        assert_eq!(m.latency, 1e-4);
        assert_eq!(m.bandwidth, 1e9);
        assert_eq!(m.model_bytes_override, Some(45_000_000));
        assert_eq!(m.batch_time, 0.1);
        assert_eq!(m.jitter, 0.0);

        let mut z = RunConfig::default();
        z.set("model_bytes_override", "0").unwrap();
        assert_eq!(z.cost_model().model_bytes_override, None);
        assert!(z.set("bandwidth", "fast").is_err());
    }
}
